import importlib.util
import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke
# tests and benches must see 1 device. Multi-device tests spawn
# subprocesses (tests/_subproc.py) with their own XLA_FLAGS.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    # hypothesis is a dev-only dependency (requirements-dev.txt) that
    # the runtime image may not ship; fall back to the deterministic
    # in-repo stub so the property tests still collect and run.
    _stub_path = Path(__file__).with_name("_hypothesis_stub.py")
    _spec = importlib.util.spec_from_file_location("hypothesis", _stub_path)
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod
    _spec.loader.exec_module(_mod)

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

import pytest  # noqa: E402


@pytest.fixture
def sweep_sanitizer():
    """Arm the runtime contract sanitizers around a sweep test:
    jax.transfer_guard_device_to_host("disallow") + the jax.log_compiles
    recompile watcher + the TRACE_HOOK per-bucket trace ledger. Yields a
    repro.analysis.sanitizer.SanitizerSession; see tests/test_sanitizer.py
    for the pipeline one-trace-per-bucket assertion it enables."""
    from repro.analysis import sanitizer

    with sanitizer.sweep_sanitizer() as session:
        yield session
