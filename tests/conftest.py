import os
import sys
from pathlib import Path

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke
# tests and benches must see 1 device. Multi-device tests spawn
# subprocesses (tests/_subproc.py) with their own XLA_FLAGS.
SRC = Path(__file__).resolve().parents[1] / "src"
if str(SRC) not in sys.path:
    sys.path.insert(0, str(SRC))

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from hypothesis import settings  # noqa: E402

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
