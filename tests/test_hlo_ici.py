"""HLO collective parsing + the beyond-paper ICI gating policies."""
import jax.numpy as jnp
import numpy as np

from repro.core.ici_gating import (StepPhases, reactive_policy,
                                   scheduled_policy)
from repro.launch.hlo_analysis import parse_collectives

SAMPLE_HLO = """
  %all-reduce = f32[16,1024]{1,0} all-reduce(%x), channel_id=1, replica_groups=[4,2]<=[8], use_global_device_ids=true, to_apply=%sum
  %all-gather = bf16[8,4096]{1,0} all-gather(%y), channel_id=2, replica_groups=[2,4]<=[8], dimensions={0}
  %reduce-scatter = f32[4,128]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
  %cp = bf16[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %all-reduce-done = f32[16,1024]{1,0} all-reduce-done(%ar)
  %foo = f32[2,2]{1,0} add(%a, %b)
"""


def test_parse_collectives_ops_and_sizes():
    st = parse_collectives(SAMPLE_HLO)
    by = st.by_op()
    assert by["all-reduce"]["count"] == 1
    assert by["all-gather"]["count"] == 1
    assert by["reduce-scatter"]["count"] == 1
    assert by["collective-permute"]["count"] == 1
    assert by["all-reduce"]["result_bytes"] == 16 * 1024 * 4
    assert by["all-gather"]["result_bytes"] == 8 * 4096 * 2
    # ring factors
    ar = 2 * 16 * 1024 * 4 * (2 - 1) / 2      # group size 2
    assert abs(by["all-reduce"]["link_bytes"] - ar) < 1e-6
    rs = 4 * 128 * 4 * (8 - 1)                # group size 8
    assert abs(by["reduce-scatter"]["link_bytes"] - rs) < 1e-6


def _phases(duty=0.2):
    # 100 us compute + 25 us collective per layer
    return StepPhases("x", "train_4k", n_layers=8, t_compute_us=100.0,
                      t_collective_us=25.0, t_tail_us=50.0,
                      coll_tail_us=10.0)


def test_scheduled_policy_saves_energy_at_zero_latency():
    r = scheduled_policy(_phases())
    assert r["latency_penalty"] == 0.0
    assert 0.0 < r["ici_energy_savings"] < 0.75
    # one link-pair always on -> savings ceiling is 3/4
    assert r["link_on_frac"] >= 0.25


def test_scheduled_policy_idle_scales_savings():
    busy = scheduled_policy(_phases(), idle_frac=0.0)
    idle = scheduled_policy(_phases(), idle_frac=0.8)
    assert idle["ici_energy_savings"] > busy["ici_energy_savings"]


def test_reactive_policy_pays_latency():
    ph = _phases()
    r = reactive_policy(ph)
    s = scheduled_policy(ph)
    assert r["ici_energy_savings"] <= s["ici_energy_savings"] + 0.15
    assert r["latency_penalty"] >= 0.0


def test_collective_bound_step_saves_little():
    ph = StepPhases("x", "train_4k", n_layers=8, t_compute_us=10.0,
                    t_collective_us=50.0, t_tail_us=0.0, coll_tail_us=0.0)
    r = scheduled_policy(ph)
    assert r["ici_energy_savings"] < 0.2
