"""Multi-device behaviour (8 fake CPU devices via subprocess): sharded
train step, MoE dist-vs-pure equivalence, elastic re-shard restore, and
the pipeline-parallel executor."""
import pytest

from tests._subproc import run_with_devices


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step
from repro.launch.mesh import make_test_mesh, dist_for, set_mesh
from repro.distributed import sharding as shd

cfg = dataclasses.replace(reduced(get_config("qwen3-8b")),
                          n_heads=4, n_kv=2, capacity_factor=8.0)
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
batch = {"tokens": jax.random.randint(key, (4, 16), 0, cfg.vocab),
         "targets": jax.random.randint(key, (4, 16), 0, cfg.vocab)}
opt_init, _ = make_optimizer(cfg)
opt = opt_init(params)

# single device reference
p1, o1, m1 = jax.jit(make_train_step(cfg))(params, opt, batch,
                                           jnp.zeros((), jnp.int32))

# 2x2 mesh with full sharding rules
mesh = make_test_mesh(2, 2)
dist = dist_for(mesh)
p_specs, _ = shd.param_specs(cfg, dist)
from jax.sharding import NamedSharding, PartitionSpec
repl = NamedSharding(mesh, PartitionSpec())   # prefix: replicate subtree
with set_mesh(mesh):
    step = jax.jit(make_train_step(cfg, dist),
                   in_shardings=(shd.to_shardings(p_specs, mesh),
                                 repl, repl, repl))
    p2, o2, m2 = step(params, opt, batch, jnp.zeros((), jnp.int32))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4, (m1["loss"], m2["loss"])
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert d < 2e-3, d
print("OK sharded==single", float(m1["loss"]), float(m2["loss"]))
""")
    assert "OK sharded==single" in out


@pytest.mark.slow
def test_moe_dist_matches_pure():
    out = run_with_devices("""
import jax, jax.numpy as jnp, dataclasses
from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.launch.mesh import make_test_mesh, dist_for, set_mesh

# ep mode: 4 experts over a 2-way model axis
cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                          capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = moe_mod.moe_init(key, cfg, jnp.float32)
x = jax.random.normal(key, (4, 8, cfg.d_model))
y_pure, aux_pure = moe_mod.moe_apply_pure(p, cfg, x)
mesh = make_test_mesh(2, 2)
dist = dist_for(mesh)
with set_mesh(mesh):
    y_dist, aux_dist = jax.jit(
        lambda p, x: moe_mod.moe_apply_dist(p, cfg, x, dist))(p, x)
err = float(jnp.max(jnp.abs(y_pure - y_dist)))
assert err < 2e-4, err
assert abs(float(aux_pure) - float(aux_dist)) < 1e-4
print("OK moe dist==pure", err, "mode:", moe_mod.ep_mode(cfg, dist))
""")
    assert "OK moe dist==pure" in out


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    out = run_with_devices(f"""
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step
from repro.launch.mesh import make_test_mesh, dist_for, set_mesh
from repro.distributed import sharding as shd
from repro.checkpoint.checkpointer import save, restore

cfg = reduced(get_config("qwen3-0.6b"))
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
opt_init, _ = make_optimizer(cfg)
state = {{"params": params, "opt": opt_init(params)}}

# "train" on a 4x2 mesh, checkpoint
mesh_a = make_test_mesh(4, 2)
dist_a = dist_for(mesh_a)
batch = {{"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab),
          "targets": jax.random.randint(key, (8, 16), 0, cfg.vocab)}}
with set_mesh(mesh_a):
    step = jax.jit(make_train_step(cfg, dist_a))
    p, o, m = step(state["params"], state["opt"], batch,
                   jnp.zeros((), jnp.int32))
save(r"{tmp_path}", {{"params": p, "opt": o}}, step=1)

# restart on a DIFFERENT (2x2, half the devices) mesh with shardings
mesh_b = make_test_mesh(2, 2)
dist_b = dist_for(mesh_b)
p_specs, p_shapes = shd.param_specs(cfg, dist_b)
shardings = {{"params": shd.to_shardings(p_specs, mesh_b), "opt": None}}
state_b, got_step = restore(r"{tmp_path}", {{"params": p, "opt": o}})
assert got_step == 1
with set_mesh(mesh_b):
    step_b = jax.jit(make_train_step(cfg, dist_b))
    p2, o2, m2 = step_b(state_b["params"], state_b["opt"], batch,
                        jnp.zeros((), jnp.int32) + 1)
assert jnp.isfinite(m2["loss"])
print("OK elastic restore", float(m["loss"]), float(m2["loss"]))
""")
    assert "OK elastic restore" in out


@pytest.mark.slow
def test_pipeline_parallel_executor():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply

n_stages = 4
mesh = jax.make_mesh((n_stages,), ("stage",))
key = jax.random.PRNGKey(0)
Ws = jax.random.normal(key, (n_stages, 16, 16)) * 0.3

def layer_fn(W, x):
    return jnp.tanh(x @ W)

x = jax.random.normal(jax.random.fold_in(key, 1), (8, 16))
got = pipeline_apply(mesh, "stage", n_stages, layer_fn, Ws, x, n_micro=4)
want = x
for s in range(n_stages):
    want = layer_fn(Ws[s], want)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("OK pipeline parallel")
""", n_devices=4)
    assert "OK pipeline parallel" in out
