"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lcdc_switch import switch_step
from repro.kernels.rwkv6_wkv import wkv_chunked
from repro.models.attention import chunked_attention

ATTN_CASES = [
    # (B, T, S, H, dh, causal, swa, dtype, blocks)
    (1, 64, 64, 1, 32, True, 0, jnp.float32, 32),
    (2, 128, 128, 2, 64, True, 0, jnp.float32, 64),
    (2, 128, 128, 2, 64, False, 0, jnp.float32, 64),
    (1, 128, 128, 2, 64, True, 32, jnp.float32, 32),
    (1, 128, 128, 1, 128, True, 0, jnp.bfloat16, 64),
    (1, 64, 64, 2, 80, False, 0, jnp.float32, 32),   # hubert head dim
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_vs_naive(case):
    B, T, S, H, dh, causal, swa, dtype, blk = case
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, H, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, H, dh)).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, swa_window=swa,
                          block_q=blk, block_k=blk)
    expect = ref.attention_naive(q, k, v, causal=causal, swa_window=swa)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol,
                               rtol=tol)


def test_chunked_attention_is_also_a_valid_oracle():
    """The model's chunked attention (the CPU execution path) must agree
    with the naive softmax too."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 96, 2, 48))
    k = jax.random.normal(ks[1], (2, 96, 2, 48))
    v = jax.random.normal(ks[2], (2, 96, 2, 48))
    a = chunked_attention(q, k, v, causal=True, chunk_q=32, chunk_k=32)
    b = ref.attention_naive(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


WKV_CASES = [
    (1, 32, 1, 8, 16, jnp.float32),
    (2, 64, 3, 16, 16, jnp.float32),
    (2, 48, 2, 32, 16, jnp.float32),
    (1, 64, 2, 16, 8, jnp.float32),
    (1, 32, 2, 16, 16, jnp.bfloat16),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_wkv_chunked_vs_sequential(case):
    B, T, H, dh, chunk, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    r = (jax.random.normal(ks[0], (B, T, H, dh)) * 0.5).astype(dtype)
    k = (jax.random.normal(ks[1], (B, T, H, dh)) * 0.5).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, dh)).astype(dtype)
    # realistic RWKV-6 decay range: w = exp(-exp(x))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, T, H, dh)) * 0.5)) \
        .astype(dtype)
    u = (jax.random.normal(ks[4], (H, dh)) * 0.3).astype(dtype)
    s0 = jax.random.normal(ks[5], (B, H, dh, dh)) * 0.1
    y1, sT1 = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    y2, sT2 = ref.wkv_ref(r, k, v, w, u, s0)
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(sT1), np.asarray(sT2), atol=tol,
                               rtol=tol)


# odd S (16, 100) exercises the switch-axis padding: tiers need not be
# a multiple of the block (e.g. the 16-CSW tier under a 128 block)
@pytest.mark.parametrize("S,L,block", [(128, 4, 64), (256, 4, 128),
                                       (128, 8, 128), (16, 4, 128),
                                       (100, 4, 64)])
def test_switch_step_vs_ref(S, L, block):
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 3)
    q = jax.random.uniform(ks[0], (S, L)) * 20
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    arr = jax.random.uniform(ks[2], (S,)) * 3
    a = switch_step(q, stage, arr, block_s=block)
    b = ref.switch_step_ref(q, stage, arr)
    assert len(a) == len(b) == 8
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)


# the extended datapath the simulator hot loop uses: K-component queues
# ([intra, inter] split), per-switch arrival vectors, draining top
# ports, multi-pkt serve rates, and non-default cap/watermarks
@pytest.mark.parametrize("S,L,K,serve_rate,block",
                         [(128, 4, 2, 1.0, 64), (16, 4, 2, 1.0, 128),
                          (64, 4, 1, 4.0, 32), (96, 8, 3, 2.0, 64)])
def test_switch_step_components_vs_ref(S, L, K, serve_rate, block):
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.uniform(ks[0], (S, L, K)) * 15
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    arr = jax.random.uniform(ks[2], (S, K)) * 2
    drain = jax.random.bernoulli(ks[3], 0.4, (S,))
    kw = dict(cap=17.0, hi=0.6, lo=0.3, serve_rate=serve_rate)
    a = switch_step(q, stage, arr, drain, block_s=block, **kw)
    b = ref.switch_step_ref(q, stage, arr, drain, **kw)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)


@pytest.mark.parametrize("S,L,K,block", [(64, 4, 2, 32), (100, 3, 1, 128)])
def test_switch_step_valid_mask_vs_ref(S, L, K, block):
    """The multi-site padding mask: Pallas matches the ref oracle, and
    invalid switches are inert (queues pass through, nothing served,
    no triggers, no drops)."""
    ks = jax.random.split(jax.random.PRNGKey(13), 4)
    q = jax.random.uniform(ks[0], (S, L, K)) * 15
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    valid = jax.random.bernoulli(ks[2], 0.6, (S,))
    # contract: invalid switches receive zero arrivals
    arr = jax.random.uniform(ks[3], (S, K)) * 2 * valid[:, None]
    a = switch_step(q, stage, arr, valid=valid, block_s=block)
    b = ref.switch_step_ref(q, stage, arr, valid=valid)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)
    nq, served, hi_t, lo_t, drop, wait, occ_m1, occ_m2 = b
    inv = ~np.asarray(valid)
    np.testing.assert_allclose(np.asarray(nq)[inv], np.asarray(q)[inv])
    assert np.all(np.asarray(served)[inv] == 0)
    assert np.all(np.asarray(hi_t)[inv] == 0)
    assert np.all(np.asarray(lo_t)[inv] == 0)
    assert np.all(np.asarray(drop)[inv] == 0)
    # the delay-histogram taps are inert on padded switches too
    assert np.all(np.asarray(wait)[inv] == 0)
    assert np.all(np.asarray(occ_m1)[inv] == 0)
    assert np.all(np.asarray(occ_m2)[inv] == 0)


@pytest.mark.parametrize("S,L,K,block", [(64, 4, 2, 32), (100, 4, 1, 128)])
def test_switch_step_per_link_valid_vs_ref(S, L, K, block):
    """The fault-injection axis: valid may be a PER-LINK (S, L) mask.
    Pallas matches the ref oracle; a dead port never serves and never
    receives the enqueue pick; a live switch whose ports are ALL dead
    counts its fed arrivals as drops (no silent loss)."""
    ks = jax.random.split(jax.random.PRNGKey(17), 4)
    q = jax.random.uniform(ks[0], (S, L, K)) * 15
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    link_valid = jax.random.bernoulli(ks[2], 0.55, (S, L))
    # force a few all-dead switches so the whole-switch-outage drop
    # accounting is actually exercised
    link_valid = link_valid.at[:4].set(False)
    arr = jax.random.uniform(ks[3], (S, K)) * 2
    a = switch_step(q, stage, arr, valid=link_valid, block_s=block)
    b = ref.switch_step_ref(q, stage, arr, valid=link_valid)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)
    nq, served, _, _, drop, _, _, _ = b
    dead = ~np.asarray(link_valid)
    # dead ports: untouched backlog, zero service
    np.testing.assert_allclose(np.asarray(jnp.sum(nq, 2))[dead],
                               np.asarray(jnp.sum(q, 2))[dead])
    assert np.all(np.asarray(jnp.sum(served, 2))[dead] == 0)
    # all-dead switches drop their entire arrival vector, exactly
    alldead = dead.all(axis=1)
    assert alldead[:4].all()
    np.testing.assert_allclose(
        np.asarray(drop)[alldead],
        np.asarray(jnp.sum(arr, 1))[alldead], atol=1e-6)


def test_switch_step_per_switch_cap_vs_ref():
    """cap may be a per-switch array; must survive the padded block."""
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    S, L = 100, 4
    q = jax.random.uniform(ks[0], (S, L)) * 20
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    arr = jax.random.uniform(ks[2], (S,)) * 3
    cap = jnp.linspace(10.0, 25.0, S)
    a = switch_step(q, stage, arr, cap=cap, block_s=128)
    b = ref.switch_step_ref(q, stage, arr, cap=cap)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=1e-6)


def test_switch_step_drain_blocks_enqueue_but_serves():
    """A draining top port must keep serving its backlog while new
    arrivals go to the remaining usable ports."""
    q = jnp.array([[5.0, 9.0]])[..., None]            # (1, 2, 1)
    stage = jnp.array([2], jnp.int32)
    arr = jnp.array([[3.0]])
    drain = jnp.array([True])
    nq, served, _, _, drop, wait, _, _ = ref.switch_step_ref(
        q, stage, arr, drain, cap=20.0)
    # arrival lands on port 0 (only usable), port 1 still drains 1 pkt
    np.testing.assert_allclose(np.asarray(nq[0, :, 0]), [7.0, 8.0])
    np.testing.assert_allclose(np.asarray(served[0, :, 0]), [1.0, 1.0])
    assert float(drop[0]) == 0.0
    # the arrival queues behind port 0's 5 existing pkts (not the
    # draining port's 9): backlog-age 5 ticks at serve_rate 1
    assert float(wait[0]) == 5.0


def test_switch_step_moment_taps_vs_direct():
    """The backlog-age / occupancy-moment outputs equal what a direct
    recomputation from the returned queues gives: enq_wait is the
    min-usable-port backlog over serve_rate, occ_m1/m2 are the first two
    moments of the post-serve per-port backlogs."""
    from repro.core import gating
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    S, L, K, rate = 64, 4, 2, 4.0
    q = jax.random.uniform(ks[0], (S, L, K)) * 15
    stage = jax.random.randint(ks[1], (S,), 1, L + 1)
    arr = jax.random.uniform(ks[2], (S, K)) * 2
    nq, served, _, _, _, wait, m1, m2 = ref.switch_step_ref(
        q, stage, arr, serve_rate=rate)
    usable = np.asarray(gating.usable_links(
        stage, jnp.zeros((S,), bool), L))
    qtot = np.asarray(jnp.sum(q, axis=2))
    mn = np.min(np.where(usable, qtot, np.inf), axis=1)
    np.testing.assert_allclose(np.asarray(wait), mn / rate, atol=1e-6)
    qpost = np.asarray(jnp.sum(nq, axis=2))
    np.testing.assert_allclose(np.asarray(m1), qpost.sum(1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(m2), (qpost ** 2).sum(1),
                               atol=1e-4)


def test_wkv_kernel_plugs_into_model():
    """ops.model_kernel_fns routes the rwkv model through the Pallas wkv."""
    import dataclasses
    from repro.configs import get_config, reduced
    from repro.kernels.ops import model_kernel_fns
    from repro.models import model as M
    cfg = reduced(get_config("rwkv6-7b"))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab),
             "targets": jax.random.randint(key, (2, 32), 0, cfg.vocab)}
    l_ref, _ = M.train_loss(cfg, params, batch)
    l_pal, _ = M.train_loss(cfg, params, batch,
                            kernel_fns=model_kernel_fns(use_pallas=True))
    assert abs(float(l_ref) - float(l_pal)) < 1e-3


def test_flash_kernel_plugs_into_model():
    from repro.configs import get_config, reduced
    from repro.kernels.ops import model_kernel_fns
    from repro.models import model as M
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), attn_chunk=32)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 64), 0, cfg.vocab),
             "targets": jax.random.randint(key, (2, 64), 0, cfg.vocab)}
    l_ref, _ = M.train_loss(cfg, params, batch)
    l_pal, _ = M.train_loss(cfg, params, batch,
                            kernel_fns=model_kernel_fns(use_pallas=True))
    assert abs(float(l_ref) - float(l_pal)) < 1e-3
