"""Decode-from-cache must equal the full-sequence forward (per family).

MoE capacity is raised so token-drop nondeterminism between different
batch aggregations cannot mask real cache bugs.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model as M

DECODE_ARCHS = [a for a in ARCH_IDS if a != "hubert-xlarge"]


def _merge(dst, src):
    if dst.shape == src.shape:
        return src
    for ax in range(dst.ndim):
        if dst.shape[ax] != src.shape[ax]:
            sl = [slice(None)] * dst.ndim
            sl[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(sl)].set(src)
    return src


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)),
                              capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    B, T = 2, 12
    if cfg.frontend == "vision_patches":
        P = cfg.n_frontend_tokens
        patches = jax.random.normal(key, (B, P, cfg.d_model), cfg.dtype)
        toks = jax.random.randint(key, (B, T - P), 0, cfg.vocab)
        full = {"patches": patches, "tokens": toks}
        pre = {"patches": patches, "tokens": toks[:, :-1]}
        last_tok = toks[:, -1:]
    else:
        toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
        full = {"tokens": toks}
        pre = {"tokens": toks[:, :-1]}
        last_tok = toks[:, -1:]

    logits_full, _ = M.prefill(cfg, params, full)
    _, cache = M.prefill(cfg, params, pre)
    cache_full = M.init_cache(cfg, B, T, dtype=cfg.dtype)
    cache = jax.tree.map(_merge, cache_full, cache)
    pos = jnp.full((B,), T - 1, jnp.int32)
    logits_dec, new_cache = M.decode_step(cfg, params, cache, last_tok, pos)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 3e-3, f"{arch}: {err}"
    # cache tree round-trips (same treedef/shapes/dtypes) for serving loops
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_multi_step_decode_rwkv():
    """Sequential decode for 4 steps matches prefill of the longer seq."""
    cfg = reduced(get_config("rwkv6-7b"))
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    B, T = 2, 10
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    logits_full, _ = M.prefill(cfg, params, {"tokens": toks})
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :6]})
    logits = None
    for t in range(6, T):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = M.decode_step(cfg, params, cache, toks[:, t:t + 1],
                                      pos)
    err = float(jnp.max(jnp.abs(logits - logits_full)))
    assert err < 3e-3, err


def test_swa_rolling_cache_mixtral():
    """With seq > window, the rolling cache decode matches full forward."""
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")),
                              swa_window=8, capacity_factor=8.0)
    key = jax.random.PRNGKey(4)
    params = M.init_params(cfg, key)
    B, T = 2, 16  # T > window
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    logits_full, _ = M.prefill(cfg, params, {"tokens": toks})
    _, cache = M.prefill(cfg, params, {"tokens": toks[:, :-1]})
    pos = jnp.full((B,), T - 1, jnp.int32)
    logits_dec, _ = M.decode_step(cfg, params, cache, toks[:, -1:], pos)
    err = float(jnp.max(jnp.abs(logits_dec - logits_full)))
    assert err < 3e-3, err
