"""Flow-level workload engine (flow_mode=1): bit-parity of flow_mode=0
against the committed pre-flow golden results, flow-knob inertness, the
exact flow-conservation census under gating + faults, table-overflow
eviction accounting, the sampler monotonicity property, and the
one-trace / one-transfer pins on a flow batch."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import constants as C
from repro.core import simulator as S
from repro.core import workloads
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

GOLDEN = Path(__file__).with_name("data") / "preflow_golden.json"
# the golden capture's site/ticks (tests/data/preflow_golden.json
# "config"): two clusters so inter traffic exercises the CSW/FC tiers
SITE = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
              csw_per_cluster=2, n_fc=2, csw_ring_links=4, fc_ring_links=8)
HARSH = dict(wake_fail_prob=0.30, wake_jitter_frac=0.50,
             link_mtbf_ticks=5_000.0, repair_ticks=400)
TICKS, CHUNK = 1_000, 250


def _params(spec="fb_web", **kw):
    return S.SimParams(spec=TRAFFIC_SPECS[spec], site=SITE, **kw)


def _golden_runs():
    """The exact (SimParams, seed) rows of the pre-flow golden capture
    (labels fb_hadoop|lcdc|x1.6|s8, fb_hadoop|base|x1.6|s9,
    fb_web|lcdc|x1|s3)."""
    return [(_params("fb_hadoop", gating_enabled=True, rate_scale=1.6), 8),
            (_params("fb_hadoop", gating_enabled=False, rate_scale=1.6), 9),
            (_params("fb_web", gating_enabled=True), 3)]


# ---- flow_mode=0 bit-parity vs the pre-flow engine ----------------------

def test_flow_mode0_bit_identical_to_preflow_golden():
    """The tentpole contract: with flow_mode=0 (the default) every
    metric — histograms included — is BIT-identical to the engine as it
    existed before the flow subsystem, in the current x64 mode."""
    g = json.loads(GOLDEN.read_text())
    cfg = g["config"]
    batch = S.make_batch(_golden_runs())
    res = S.run_sweep(batch, cfg["ticks"], chunk_ticks=cfg["chunk_ticks"])
    rows = g["results_x64"] if jax.config.jax_enable_x64 else g["results"]
    assert [r["label"] for r in rows] == list(batch.labels)
    for want, got in zip(rows, res):
        for k, v in want.items():
            if k in ("label", "trace", "gating", "ticks"):
                continue
            assert k in got, k
            if isinstance(v, list):
                np.testing.assert_array_equal(
                    np.asarray(got[k]), np.asarray(v), err_msg=k)
            else:
                assert got[k] == v, (k, got[k], v)


def test_flow_knobs_inert_at_mode0():
    """At flow_mode=0 the other four flow knobs must not perturb ANY
    result bit (same seed, wildly different flow knobs)."""
    plain = _params("fb_hadoop", gating_enabled=True, rate_scale=1.6)
    weird = _params("fb_hadoop", gating_enabled=True, rate_scale=1.6,
                    flow_arrival_rate=0.7, flow_size_dist="datamining",
                    incast_degree=C.MAX_INCAST_DEGREE, flow_table_cap=3)
    res = S.run_sweep(S.make_batch([(plain, 8), (weird, 8)]),
                      TICKS, chunk_ticks=CHUNK)
    for k, v in res[0].items():
        if isinstance(v, list):
            np.testing.assert_array_equal(
                np.asarray(res[1][k]), np.asarray(v), err_msg=k)
        else:
            assert res[1][k] == v, k


# ---- the flow engine itself ---------------------------------------------

@pytest.fixture(scope="module")
def flow_results():
    """One sweep over the canonical flow modes: light websearch under
    LC/DC, light datamining always-on, websearch under LC/DC + harsh
    optical faults, and the incast/table-pressure row."""
    rows = {
        "web": _params(flow_mode=1, flow_arrival_rate=0.05),
        "dm_base": _params(flow_mode=1, flow_arrival_rate=0.05,
                           flow_size_dist="datamining",
                           gating_enabled=False),
        "faulty": _params(flow_mode=1, flow_arrival_rate=0.05, **HARSH),
        "incast": _params(flow_mode=1, flow_arrival_rate=0.3,
                          incast_degree=8, flow_table_cap=8),
    }
    batch = S.make_batch([(p, 4 + i) for i, p in enumerate(rows.values())])
    res, state = S.run_sweep(batch, TICKS, chunk_ticks=CHUNK,
                             return_state=True)
    caps = {k: p.flow_table_cap for k, p in rows.items()}
    return dict(zip(rows, res)), state, caps


def _in_table(state, row, cap):
    rem = np.asarray(state.ft_rem)[row]
    return float(np.sum((rem > 0)
                        & (np.arange(rem.shape[1])[None, :] < cap)))


def test_flow_conservation_exact(flow_results):
    """started == completed + evicted + still-in-table, EXACTLY, in
    every mode — gating churn, harsh faults, and forced eviction
    included (counts are integral, the census must close)."""
    res, state, caps = flow_results
    for i, (mode, r) in enumerate(res.items()):
        resid = r["flows_started"] - (r["flows_completed"]
                                      + r["flows_evicted"]
                                      + _in_table(state, i, caps[mode]))
        assert resid == 0.0, (mode, resid)
        assert r["flows_started"] > 0, mode


def test_flow_eviction_accounting(flow_results):
    """8-way incast into an 8-slot table must evict; light rows must
    not (the table never fills at 0.05 arrivals/tick)."""
    res, _, _ = flow_results
    assert res["incast"]["flows_evicted"] > 0
    assert res["incast"]["flow_evicted_frac"] > 0.5
    for mode in ("web", "dm_base"):
        assert res[mode]["flows_evicted"] == 0.0, mode


def test_flow_fct_metrics_sane(flow_results):
    """Completions happen, slowdowns are >= 1 (FCT >= ideal FCT by
    construction), and per-class completion counts sum to the total."""
    res, _, _ = flow_results
    for mode, r in res.items():
        assert r["flows_completed"] > 0, mode
        for k in ("fct_slowdown_p50", "fct_slowdown_p99",
                  "fct_slowdown_mean"):
            assert r[k] >= 1.0, (mode, k, r[k])
        assert r["fct_p99_us"] >= r["fct_p50_us"], mode
        per_class = sum(r[f"flows_completed_{c}"]
                        for c in workloads.FLOW_CLASS_NAMES)
        assert per_class == r["flows_completed"], mode


def test_flow_wake_stalls_attributed(flow_results):
    """Under LC/DC the wake-stall delay attribution rides into the
    sampled path delay FCT uses — the gated flow rows must show it,
    and the harsh-fault row must actually exercise the fault model
    (its stalls flow through the same ``gating.stall_attribution``
    seam; the rare all-uplinks-dead fallback event itself is not
    guaranteed inside a 1000-tick light-load run)."""
    res, _, _ = flow_results
    assert res["web"]["delay_wake_stall_us"] > 0.0
    assert res["faulty"]["wake_retries"] > 0
    assert res["faulty"]["link_fault_frac"] > 0.0


def test_flow_validate_mode_clean():
    """The in-program validate guard (finite + packet conservation +
    flow census) passes on a flow batch."""
    batch = S.make_batch([
        (_params(flow_mode=1, flow_arrival_rate=0.1), 1),
        (_params(flow_mode=1, flow_arrival_rate=0.3, incast_degree=8,
                 flow_table_cap=8), 2)])
    S.run_sweep(batch, 500, chunk_ticks=250, validate=True)


def test_flow_batch_one_trace_one_transfer():
    """A flow grid is still ONE compile and ONE device->host fetch
    (flow knobs are Scenario leaves — no new compile sites)."""
    batch = S.make_batch([
        (_params(flow_mode=1, flow_arrival_rate=0.05), 1),
        (_params(flow_mode=1, flow_size_dist="datamining",
                 flow_arrival_rate=0.2, incast_degree=4), 2),
        (_params(), 3)])
    # unique chunk length => a fresh trace even after the other tests
    t0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
    S.run_sweep(batch, 422, chunk_ticks=211)
    assert S.TRACE_COUNT - t0 == 1
    assert S.HOST_TRANSFER_COUNT - h0 == 1


# ---- the sampler property -----------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(0, len(workloads.FLOW_DIST_NAMES) - 1),
       st.floats(0.0, 0.999999),
       st.floats(0.0, 0.999999))
def test_flow_size_sampler_monotone_integral(dist, u1, u2):
    """Inverse-CDF sampling is monotone in the uniform (within and
    across anchor segments) and yields integral sizes >= 1."""
    lo, hi = sorted((u1, u2))
    s = np.asarray(workloads.sample_flow_size_pkts(
        jnp.asarray([lo, hi], jnp.float32), dist))
    assert s[0] <= s[1]
    assert (s >= 1.0).all()
    assert (s == np.floor(s)).all()
    assert s[1] <= workloads.CDF_SIZE_PKTS[dist].max()


def test_flow_size_classes():
    lo, hi = workloads.FLOW_CLASS_EDGES_PKTS
    got = np.asarray(workloads.flow_size_class(
        jnp.asarray([1, lo, lo + 1, hi, hi + 1], jnp.float32)))
    np.testing.assert_array_equal(got, [0, 0, 1, 1, 2])


# ---- knob plumbing ------------------------------------------------------

def test_flow_fingerprint_tracks_knobs():
    assert tuple(S.flow_fingerprint()) == S.FLOW_KNOBS
    assert S.flow_fingerprint(_params()) == S.flow_fingerprint()
    assert S.flow_fingerprint(_params(flow_mode=1)) != S.flow_fingerprint()


@pytest.mark.parametrize("kw,match", [
    (dict(flow_mode=2), "flow_mode"),
    (dict(flow_arrival_rate=-0.1), "flow_arrival_rate"),
    (dict(flow_arrival_rate=1.5), "flow_arrival_rate"),
    (dict(flow_size_dist="cachefollower"), "flow_size_dist"),
    (dict(incast_degree=0), "incast_degree"),
    (dict(incast_degree=C.MAX_INCAST_DEGREE + 1), "incast_degree"),
    (dict(flow_table_cap=0), "flow_table_cap"),
    (dict(flow_table_cap=C.FLOW_TABLE_SLOTS + 1), "flow_table_cap"),
])
def test_simparams_rejects_bad_flow_knobs(kw, match):
    with pytest.raises(ValueError, match=match):
        _params(**kw)
