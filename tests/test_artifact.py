"""Compiled-artifact auditor tests (RL007/RL008/RL009).

Three layers, mirroring the auditor's own split:

* a **fixture corpus of mutated HLO text** drives the pure checkers
  with injected violations — collective on the batch axis, host
  callback/infeed, lost donation aliasing, wrong fold dtype, memory
  over budget, cost drift — pinning the EXACT rule ID each one raises
  (no jax import);
* **contract-level audits** of the real engine: the shipped tree +
  committed contracts must audit clean (in-process x32, subprocess
  x64 and 4-fake-device sharded legs via tests/_subproc.py), and a
  mutated contracts file must raise RL007 and flip the CLI ``--check``
  exit code to 1;
* the **planner calibration** surface: every audited hull reports a
  model-vs-measured ratio and the spread stays within the contract.
"""
import textwrap
import types
from pathlib import Path

import pytest

from repro.analysis import artifact as A
from repro.analysis import hlo

from tests._subproc import run_with_devices

REPO = Path(__file__).resolve().parents[1]


# ---- fixture corpus: mutated HLO text -> exact rule IDs -----------------

CLEAN_HLO = """\
HloModule jit__sweep_chunk_impl, entry_computation_layout={(f32[4,64]{1,0})->f32[4,64]{1,0}}

ENTRY %main.5 (p0.1: f32[4,64]) -> f32[4,64] {
  %p0.1 = f32[4,64]{1,0} parameter(0)
  %add.2 = f32[4,64]{1,0} add(f32[4,64]{1,0} %p0.1, f32[4,64]{1,0} %p0.1)
  ROOT %multiply.3 = f32[4,64]{1,0} multiply(%add.2, %p0.1)
}
"""

ALLREDUCE_HLO = CLEAN_HLO.replace(
    "ROOT %multiply.3",
    "%all-reduce.9 = f32[4,64]{1,0} all-reduce(f32[4,64]{1,0} %add.2), "
    "replica_groups=[1,4], to_apply=%region_0.4\n  ROOT %multiply.3")

CALLBACK_HLO = CLEAN_HLO.replace(
    "ROOT %multiply.3",
    '%custom-call.7 = (f32[4,64]{1,0}, s32[]) custom-call(%add.2), '
    'custom_call_target="xla_python_cpu_callback"\n  ROOT %multiply.3')

INFEED_HLO = CLEAN_HLO.replace(
    "ROOT %multiply.3",
    "%infeed.6 = ((f32[4,64]{1,0}), token[]) infeed(token[] %tok.5)\n"
    "  ROOT %multiply.3")

ALIASED_HLO = CLEAN_HLO.replace(
    "entry_computation_layout",
    "input_output_alias={ {0}: (0, {}, may-alias), {1}: (1, {}, "
    "may-alias) }, entry_computation_layout")


def rules(findings):
    return [f.rule for f in findings]


def test_fixture_clean_hlo_passes_everything():
    assert A.check_collectives_text(CLEAN_HLO, [], "p", "w") == []
    assert A.check_host_ops_text(CLEAN_HLO, "p", "w") == []
    assert hlo.count_alias_entries(CLEAN_HLO) == 0


def test_fixture_injected_allreduce_is_rl008():
    got = A.check_collectives_text(ALLREDUCE_HLO, [], "p", "w")
    assert rules(got) == ["RL008"]
    assert "all-reduce" in got[0].message
    # ring all-reduce over g=4: 2 * 4*64*4B * 3/4 link-bytes
    assert "1536 link-bytes" in got[0].message
    # the allow-list is honored (a reviewed contract edit blesses it)
    assert A.check_collectives_text(ALLREDUCE_HLO, ["all-reduce"],
                                    "p", "w") == []


def test_fixture_injected_callback_and_infeed_are_rl008():
    got = A.check_host_ops_text(CALLBACK_HLO, "p", "w")
    assert rules(got) == ["RL008"]
    assert "xla_python_cpu_callback" in got[0].message
    got = A.check_host_ops_text(INFEED_HLO, "p", "w")
    assert rules(got) == ["RL008"]
    assert "infeed" in got[0].message


def test_fixture_alias_header_parses():
    assert hlo.count_alias_entries(ALIASED_HLO) == 2


def test_fixture_donation_loss_is_rl009():
    ok_mem = {"alias_size_in_bytes": 7568}
    assert A.check_donation(ok_mem, 139, 7568, 1.0, "p", "w") == []
    # aliasing silently dropped by XLA -> donation lost
    got = A.check_donation({"alias_size_in_bytes": 0}, 0, 7568, 1.0,
                           "p", "w")
    assert rules(got) == ["RL009"]
    # partial aliasing below the contract fraction is also a loss
    got = A.check_donation({"alias_size_in_bytes": 100}, 2, 7568, 1.0,
                           "p", "w")
    assert rules(got) == ["RL009"]
    # nothing donated -> nothing to check
    assert A.check_donation({"alias_size_in_bytes": 0}, 0, 0, 1.0,
                            "p", "w") == []


def test_fixture_fold_dtype_drift_is_rl007():
    assert A.check_fold_dtype("float32", "float32", "p", "w") == []
    got = A.check_fold_dtype("float64", "float32", "p", "w")
    assert rules(got) == ["RL007"]
    assert "_fold_dtype" in got[0].message


def test_fixture_memory_over_budget_is_rl007():
    mem = {"temp_size_in_bytes": 90_000, "output_size_in_bytes": 20_000}
    assert A.check_memory_budget(mem, 120_000, "p", "w") == []
    got = A.check_memory_budget(mem, 100_000, "p", "w")
    assert rules(got) == ["RL007"]
    assert "110000 B" in got[0].message
    # budget 0 = unset (bless fills it): never fires
    assert A.check_memory_budget(mem, 0, "p", "w") == []


def test_fixture_cost_drift_is_rl007():
    blessed = {"flops_per_scen": 1000.0, "bytes_per_scen": 2000.0}
    ok = {"flops_per_scen": 1400.0, "bytes_per_scen": 2100.0}
    assert A.check_cost_drift(ok, blessed, 0.5, "x32", "p", "w") == []
    bad = {"flops_per_scen": 1501.0, "bytes_per_scen": 2100.0}
    got = A.check_cost_drift(bad, blessed, 0.5, "x32", "p", "w")
    assert rules(got) == ["RL007"]
    assert "FLOPs" in got[0].message
    # both axes drifted -> one finding each
    bad = {"flops_per_scen": 1501.0, "bytes_per_scen": 4000.0}
    assert rules(A.check_cost_drift(bad, blessed, 0.5, "x32", "p",
                                    "w")) == ["RL007", "RL007"]


def test_fixture_unblessed_mode_is_rl007():
    got = A.check_cost_drift({"flops_per_scen": 1.0}, None, 0.5, "x64",
                             "p", "w")
    assert rules(got) == ["RL007"]
    assert "--bless-artifacts" in got[0].message


def test_fixture_coverage_miss_is_rl007():
    cfg = types.SimpleNamespace(raw={"compile_site": [
        {"file": "src/a.py", "qualname": "f"},
        {"file": "src/b.py", "qualname": "g.inner"},
        {"file": "src/c.py", "qualname": "h"},
    ]})
    art = {"unit": [{"covers": ["src/a.py::f", "src/b.py::g"]}],
           "skip": [{"file": "src/c.py", "qualname": "h",
                     "reason": "why not"}]}
    assert A.check_coverage(cfg, art) == []   # exact, prefix, skip
    art["skip"] = []
    got = A.check_coverage(cfg, art)
    assert rules(got) == ["RL007"]
    assert "src/c.py::h" in got[0].message
    # a skip without a reason is itself a finding
    art["skip"] = [{"file": "src/c.py", "qualname": "h", "reason": " "}]
    assert rules(A.check_coverage(cfg, art)) == ["RL007"]


def test_fixture_calibration_spread_is_rl007():
    cal = {"ratio_spread": 1.2, "hulls": [{"tag": "a", "ratio": 3.0}]}
    assert A.check_calibration(cal, 2.0) == []
    cal = {"ratio_spread": 2.5,
           "hulls": [{"tag": "2x2c2f2", "ratio": 2.0},
                     {"tag": "4x8c4f4", "ratio": 5.0}]}
    got = A.check_calibration(cal, 2.0)
    assert rules(got) == ["RL007"]
    assert got[0].path == "src/repro/core/planner.py"
    assert "cost_model='hlo'" in got[0].message


def test_host_op_regex_tuple_and_plain_forms():
    # real infeed results are tuples; send/recv are plain-typed
    assert hlo.find_host_ops(
        "  %s.1 = f32[4]{0} send(%p, %tok), channel_id=1\n") == ["send"]
    assert hlo.find_host_ops(
        "  %o.2 = token[] outfeed(%data, %tok)\n") == ["outfeed"]
    # not fooled by a variable merely named like an op
    assert hlo.find_host_ops(
        "  %x = f32[4]{0} add(%send_buf, %p)\n") == []


# ---- contract-level: the shipped tree audits clean ----------------------

def load_repo_cfg():
    from repro.analysis.registry import load_config
    return load_config(REPO)


def test_shipped_tree_audits_clean_x32():
    """The committed engine + committed contracts: zero findings under
    the current (x32) mode — the full audit the artifact-canary runs."""
    findings, payload = A.run_audit(REPO, load_repo_cfg())
    assert findings == [], "\n".join(f.format() for f in findings)
    assert set(payload["units"]) == {"sweep_chunk", "run_sim",
                                     "ici_reactive"}
    assert payload["mode"]["x64"] is False
    cal = payload["calibration"]
    assert cal["hulls"], "calibration must cover the sweep hulls"
    assert cal["ratio_spread"] <= 2.0
    # site_cost models the step as bandwidth-bound: every hull's
    # arithmetic intensity sits far below the TPU ridge point
    assert all(0 < h["ridge_frac"] < 1 for h in cal["hulls"])
    # the donation probe must prove full aliasing on CPU
    probes = [c["alias"] for c in payload["units"]["sweep_chunk"]["cases"]
              if c["alias"]]
    assert probes and all(
        p["alias_size"] >= p["donated_bytes"] and p["entries"] > 0
        for p in probes)
    # chunk programs are device-resident and lane-independent
    for u in payload["units"].values():
        for c in u["cases"]:
            assert c["collectives"] == {}
            assert c["host_ops"] == 0


def test_audit_clean_x64_subprocess():
    """Dual-mode leg: the committed contracts hold under x64 too (fold
    dtype flips to float64, the x64 measured band applies)."""
    code = textwrap.dedent(f"""
        import os
        os.environ["JAX_ENABLE_X64"] = "1"
        from pathlib import Path
        from repro.analysis import artifact
        from repro.analysis.registry import load_config
        root = Path({str(REPO)!r})
        findings, payload = artifact.run_audit(
            root, load_config(root), units=["run_sim", "ici_reactive"])
        assert payload["mode"]["x64"] is True
        assert findings == [], [f.format() for f in findings]
        print("X64-AUDIT-", "CLEAN", sep="")
    """)
    assert "X64-AUDIT-CLEAN" in run_with_devices(code, n_devices=1)


def test_audit_sweep_sharded_4dev_subprocess():
    """Sharded leg: with 4 fake devices the chunk program runs under
    NamedSharding on the scenario axis — still zero collectives, zero
    host ops, and the per-scenario-normalized cost stays in the same
    blessed band (the measurement is leg-invariant)."""
    code = textwrap.dedent(f"""
        from pathlib import Path
        from repro.analysis import artifact
        from repro.analysis.registry import load_config
        root = Path({str(REPO)!r})
        findings, payload = artifact.run_audit(
            root, load_config(root), units=["sweep_chunk"])
        assert findings == [], [f.format() for f in findings]
        cases = payload["units"]["sweep_chunk"]["cases"]
        assert all(c["shards"] == 4 for c in cases), cases
        assert all(c["collectives"] == {{}} and c["host_ops"] == 0
                   for c in cases)
        print("SHARDED-AUDIT-", "CLEAN", sep="")
    """)
    assert "SHARDED-AUDIT-CLEAN" in run_with_devices(code, n_devices=4)


# ---- contract-level: injected violations flip the exit code -------------

MUTATED_CONTRACTS = """\
[artifact]
schema_version = 1
cost_rtol = 0.5
min_alias_frac = 1.0
max_ratio_spread = 2.0

[[artifact.unit]]
name = "ici_reactive"
builder = "ici_reactive"
file = "src/repro/core/ici_gating.py"
covers = ["src/repro/core/ici_gating.py::_reactive_program"]
collectives_allowed = []

[[artifact.unit.case]]
tag = "t256"
n_ticks = 256
tick_us = 1.0
peak_bytes_budget = 1

[artifact.unit.case.measured.x32]
flops_per_scen = 511000.0
bytes_per_scen = 2627.0

[artifact.unit.case.measured.x64]
flops_per_scen = 520000.0
bytes_per_scen = 4875.0

[[artifact.unit.case]]
tag = "t128"
n_ticks = 128
tick_us = 1.0
"""


@pytest.fixture(scope="module")
def mutated_contracts(tmp_path_factory):
    p = tmp_path_factory.mktemp("contracts") / "mutated.toml"
    p.write_text(MUTATED_CONTRACTS)
    return p


def test_mutated_contracts_raise_rl007(mutated_contracts):
    """One audit run, three injected violations: memory budget of 1
    byte, a 1000x-drifted blessed FLOPs band, and an unblessed case."""
    findings, _ = A.run_audit(REPO, load_repo_cfg(), mutated_contracts,
                              units=["ici_reactive"])
    msgs = [f.message for f in findings]
    assert rules(findings) == ["RL007"] * 3, msgs
    assert any("exceeds the contract budget 1 B" in m for m in msgs)
    assert any("drifted beyond" in m for m in msgs)
    assert any("--bless-artifacts" in m for m in msgs)


def test_cli_check_exits_nonzero_on_artifact_violation(mutated_contracts):
    from repro.analysis.cli import main
    rc = main(["--check", "--root", str(REPO),
               "--artifact-contracts", str(mutated_contracts),
               "--artifact-units", "ici_reactive", "-q"])
    assert rc == 1


def test_schema_version_mismatch_is_rl007(tmp_path):
    p = tmp_path / "contracts.toml"
    p.write_text("[artifact]\nschema_version = 99\n")
    findings, _ = A.run_audit(REPO, load_repo_cfg(), p, units=[])
    assert rules(findings) == ["RL007"]
    assert "schema_version" in findings[0].message


# ---- planner calibration surface ----------------------------------------

def test_hlo_cost_table_reads_committed_contracts():
    table = A.hlo_cost_table(REPO)
    # the three non-validate sweep hulls, keyed by full site tag
    assert len(table) == 3
    for tag, entry in table.items():
        assert entry["flops_per_tick_scen"] > 0
        assert "s" in tag and "r" in tag            # full_site_tag form
    # x64 band is distinct (float64 arithmetic costs more)
    t64 = A.hlo_cost_table(REPO, mode="x64")
    assert set(t64) == set(table)
    assert all(t64[k]["flops_per_tick_scen"]
               > table[k]["flops_per_tick_scen"] for k in table)
