"""Energy models (Fig 1 / Fig 11) and the node-level hiding condition."""
import pytest
from hypothesis import given, strategies as st

from repro.core import constants as C
from repro.core.energy import (dc_savings, final_network_fractions,
                               power_breakdown_series)
from repro.core.node_model import (NodeTiming, STACK_STAGES,
                                   default_timing, hiding_condition,
                                   max_hideable_laser_on_us)
from repro.core.topology import all_designs, fb_site_design, FBSite


def test_stack_budget_is_3750ns():
    assert sum(ns for _, ns in STACK_STAGES) == 3750


def test_stack_stages_in_sync_with_constants():
    """node_model.STACK_STAGES and constants.TCP_STACK_NS describe the
    same measured pipeline: stage-by-stage identical, and the 3.75 us
    total is the budget the measured SENDMSG_TO_TX_US mean (3.2 us)
    stays within — the slack is what hides the laser."""
    assert tuple(ns for _, ns in STACK_STAGES) == C.TCP_STACK_NS
    assert sum(C.TCP_STACK_NS) == 3750
    assert C.SENDMSG_TO_TX_US * 1000 <= sum(C.TCP_STACK_NS)


def test_laser_turn_on_hidden():
    t = default_timing()
    assert t.hidden and t.added_latency_ns == 0.0
    assert hiding_condition(C.LASER_ON_US)


def test_max_hideable_exceeds_sfp_requirement():
    assert max_hideable_laser_on_us() >= 3.0     # >> the 1 us SFP+ turn-on


@given(st.floats(0.01, 10.0))
def test_property_hiding_condition(laser_us):
    hidden = hiding_condition(laser_us)
    assert hidden == (laser_us + C.CDR_LOCK_US <= C.SENDMSG_TO_TX_US)


@given(st.floats(0.01, 20.0))
def test_property_timing_agrees_with_hiding_condition(laser_us):
    """NodeTiming.added_latency_ns and hiding_condition must agree for
    ALL laser turn-on times, including the non-hidden regime: hidden iff
    zero added latency, and a non-hidden laser adds exactly the excess
    over the sendmsg->transmit window."""
    t = NodeTiming(stack_ns=int(C.SENDMSG_TO_TX_US * 1000),
                   laser_on_ns=int(laser_us * 1000),
                   cdr_ns=C.CDR_LOCK_US * 1000)
    assert t.hidden == (t.added_latency_ns == 0.0)
    # the int() ns truncation can only make the laser LOOK faster, so
    # the timing model may hide a laser the (exact) condition rejects
    # within one truncated ns — compare on the timing's own terms
    assert t.hidden == hiding_condition(t.laser_on_ns / 1000.0)
    excess = (t.laser_on_ns + t.cdr_ns) - t.stack_ns
    assert t.added_latency_ns == pytest.approx(max(0.0, excess))
    assert t.added_latency_ns >= 0.0


def test_fig1_network_fraction_grows():
    """With every optimization the network share of DC power rises; the
    oversubscribed fb_clos is the sparsest fabric, the average across
    designs crosses 25% (paper: network 'becomes a major component')."""
    fracs = []
    for d in all_designs():
        series = power_breakdown_series(d, util=0.30)
        net = [sum(v for k, v in frac.items() if k != "servers")
               for _, _, frac in series]
        assert net[0] < 0.25           # classic view: network is small
        assert net[-1] > net[1]        # optimizations expose the network
        fracs.append(net[-1])
    assert sum(fracs) / len(fracs) > 0.25
    d = fb_site_design()
    series = power_breakdown_series(d, util=0.30)
    assert sum(v for k, v in series[-1][2].items() if k != "servers") > 0.15


def test_fig1_final_transceiver_fraction():
    """Paper: transceivers ~20% avg; PHY+NIC+transceivers up to 46%."""
    fr = final_network_fractions(0.30)
    tx = [v["transceivers"] for v in fr.values()]
    full = [v["phy_nic_transceivers"] for v in fr.values()]
    assert 0.10 <= sum(tx) / len(tx) <= 0.30
    assert max(full) >= 0.35


def test_fig11_dc_savings():
    """Paper: ~12% (links only) and ~21-27% (with PHY+NIC) at 30% util
    when LC/DC leaves ~40% of transceiver power on."""
    res = dc_savings(transceiver_on_frac=0.4, util=0.30)
    avg = res["average"]
    assert 0.06 <= avg.savings_links_only <= 0.20
    assert avg.savings_with_phy_nic > avg.savings_links_only
    assert 0.15 <= avg.savings_with_phy_nic <= 0.35


def test_fig11_average_row_carries_real_mean_fraction():
    """The "average" row's transceiver_frac must be the mean over the
    designs, not a 0.0 placeholder that poisons downstream averages."""
    res = dc_savings(transceiver_on_frac=0.4, util=0.30)
    designs = [r for k, r in res.items() if k != "average"]
    expect = sum(r.transceiver_frac for r in designs) / len(designs)
    assert res["average"].transceiver_frac == pytest.approx(expect)
    assert res["average"].transceiver_frac > 0.05


def test_fb_site_counts():
    s = FBSite()
    assert s.n_servers == 6144 and s.n_racks == 128
    assert s.n_rsw_csw_links == 512 and s.n_csw_fc_links == 64
    pw = s.transceiver_power_w()
    assert pw["server"] == 6144 * 2.0
    assert pw["csw_fc"] == 64 * 2 * 2.4


def test_all_designs_have_positive_power():
    for d in all_designs():
        p = d.network_power_w()
        assert all(v > 0 for v in p.values())
