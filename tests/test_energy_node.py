"""Energy models (Fig 1 / Fig 11) and the node-level hiding condition."""
from hypothesis import given, strategies as st

from repro.core import constants as C
from repro.core.energy import (dc_savings, final_network_fractions,
                               power_breakdown_series)
from repro.core.node_model import (STACK_STAGES, default_timing,
                                   hiding_condition,
                                   max_hideable_laser_on_us)
from repro.core.topology import all_designs, fb_site_design, FBSite


def test_stack_budget_is_3750ns():
    assert sum(ns for _, ns in STACK_STAGES) == 3750


def test_laser_turn_on_hidden():
    t = default_timing()
    assert t.hidden and t.added_latency_ns == 0.0
    assert hiding_condition(C.LASER_ON_US)


def test_max_hideable_exceeds_sfp_requirement():
    assert max_hideable_laser_on_us() >= 3.0     # >> the 1 us SFP+ turn-on


@given(st.floats(0.01, 10.0))
def test_property_hiding_condition(laser_us):
    hidden = hiding_condition(laser_us)
    assert hidden == (laser_us + C.CDR_LOCK_US <= C.SENDMSG_TO_TX_US)


def test_fig1_network_fraction_grows():
    """With every optimization the network share of DC power rises; the
    oversubscribed fb_clos is the sparsest fabric, the average across
    designs crosses 25% (paper: network 'becomes a major component')."""
    fracs = []
    for d in all_designs():
        series = power_breakdown_series(d, util=0.30)
        net = [sum(v for k, v in frac.items() if k != "servers")
               for _, _, frac in series]
        assert net[0] < 0.25           # classic view: network is small
        assert net[-1] > net[1]        # optimizations expose the network
        fracs.append(net[-1])
    assert sum(fracs) / len(fracs) > 0.25
    d = fb_site_design()
    series = power_breakdown_series(d, util=0.30)
    assert sum(v for k, v in series[-1][2].items() if k != "servers") > 0.15


def test_fig1_final_transceiver_fraction():
    """Paper: transceivers ~20% avg; PHY+NIC+transceivers up to 46%."""
    fr = final_network_fractions(0.30)
    tx = [v["transceivers"] for v in fr.values()]
    full = [v["phy_nic_transceivers"] for v in fr.values()]
    assert 0.10 <= sum(tx) / len(tx) <= 0.30
    assert max(full) >= 0.35


def test_fig11_dc_savings():
    """Paper: ~12% (links only) and ~21-27% (with PHY+NIC) at 30% util
    when LC/DC leaves ~40% of transceiver power on."""
    res = dc_savings(transceiver_on_frac=0.4, util=0.30)
    avg = res["average"]
    assert 0.06 <= avg.savings_links_only <= 0.20
    assert avg.savings_with_phy_nic > avg.savings_links_only
    assert 0.15 <= avg.savings_with_phy_nic <= 0.35


def test_fb_site_counts():
    s = FBSite()
    assert s.n_servers == 6144 and s.n_racks == 128
    assert s.n_rsw_csw_links == 512 and s.n_csw_fc_links == 64
    pw = s.transceiver_power_w()
    assert pw["server"] == 6144 * 2.0
    assert pw["csw_fc"] == 64 * 2 * 2.4


def test_all_designs_have_positive_power():
    for d in all_designs():
        p = d.network_power_w()
        assert all(v > 0 for v in p.values())
