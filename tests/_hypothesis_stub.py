"""Minimal, deterministic stand-in for the `hypothesis` package.

Loaded by conftest.py ONLY when the real `hypothesis` is not installed
(see requirements-dev.txt), so the property tests still collect and run
everywhere: each @given test is executed for `max_examples` seeded draws
per strategy, always starting from the strategy's boundary values (the
draws the real hypothesis shrinks toward). Supports exactly the API
surface this repo uses: given, settings profiles, and the
lists/floats/integers strategies.
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, draw, boundary):
        self._draw = draw
        self._boundary = list(boundary)

    def example_at(self, i: int, rng: random.Random):
        if i < len(self._boundary):
            return self._boundary[i]
        return self._draw(rng)


class strategies:  # noqa: N801 - mimics the hypothesis.strategies module
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value),
                         [min_value, max_value])

    @staticmethod
    def floats(min_value: float, max_value: float) -> _Strategy:
        return _Strategy(lambda rng: rng.uniform(min_value, max_value),
                         [min_value, max_value,
                          (min_value + max_value) / 2.0])

    @staticmethod
    def lists(elements: _Strategy, min_size: int = 0,
              max_size: int = 10) -> _Strategy:
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return [elements.example_at(len(elements._boundary), rng)
                    for _ in range(n)]
        boundary = [[elements.example_at(i % max(len(elements._boundary),
                                                 1), random.Random(i))
                     for _ in range(min_size)] for i in range(2)]
        return _Strategy(draw, boundary)


class settings:  # noqa: N801 - mimics hypothesis.settings
    _profiles: dict = {}
    _active: dict = {"max_examples": 25}

    def __init__(self, **kw):
        self._kw = kw

    def __call__(self, fn):       # @settings(...) decorator form
        fn._stub_settings = self._kw
        return fn

    @classmethod
    def register_profile(cls, name, **kw):
        cls._profiles[name] = kw

    @classmethod
    def load_profile(cls, name):
        cls._active = {"max_examples": 25, **cls._profiles.get(name, {})}


def given(*strats, **kw_strats):
    def deco(fn):
        # NB: no functools.wraps — pytest follows __wrapped__ to the
        # original signature and would treat the drawn arguments as
        # fixtures; the wrapper must expose a zero-argument signature.
        def wrapper():
            # @settings may sit above @given (annotating this wrapper)
            # or below it (annotating fn) — honour either
            kw = getattr(wrapper, "_stub_settings", None) \
                or getattr(fn, "_stub_settings", settings._active)
            n = int(kw.get("max_examples", 25) or 25)
            rng = random.Random(f"stub:{fn.__module__}.{fn.__name__}")
            for i in range(n):
                drawn = [s.example_at(i, rng) for s in strats]
                drawn_kw = {k: s.example_at(i, rng)
                            for k, s in kw_strats.items()}
                fn(*drawn, **drawn_kw)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco
