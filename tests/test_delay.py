"""In-scan packet-delay distributions: histogram integrity, wake-stall
attribution, chunk-fold invariance, on_frac_hist boundary semantics and
the hull-padding power-accounting regression."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import constants as C
from repro.core import simulator as S
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

TICKS = 2_000


@pytest.fixture(scope="module")
def delay_results():
    """{LC/DC, always-on} on a loaded fb_hadoop, one sweep; also captures
    the trace count around the run (one-compile contract with the new
    histogram accumulators in the carry)."""
    batch = S.sweep_grid(traces=("fb_hadoop",), gating=(True, False),
                         rate_scales=(1.5,))
    n0 = S.TRACE_COUNT
    res = S.run_sweep(batch, TICKS, chunk_ticks=600)
    return res, S.TRACE_COUNT - n0


def test_delay_sweep_compiles_once(delay_results):
    _, traces = delay_results
    assert traces == 1


def test_histogram_normalized_and_ordered(delay_results):
    res, _ = delay_results
    for r in res:
        hist = np.asarray(r["delay_hist"])
        assert hist.shape == (C.DELAY_HIST_BINS,)
        assert abs(hist.sum() - 1.0) < 1e-6
        assert np.all(hist >= 0.0)
        # percentiles ordered and above the stack+wire floor
        assert 5.75 <= r["delay_p50_us"] <= r["delay_p95_us"] \
            <= r["delay_p99_us"]
        # the histogram mean lands inside the histogram's support
        assert S.DELAY_BIN_EDGES_US[0] <= r["delay_mean_sampled_us"]


def test_bin_edges_match_binning():
    """A sample placed exactly at a bin's lower edge lands in that bin
    (half-open [lo, hi) bins, log-spaced above DELAY_HIST_MIN_US)."""
    h0 = jnp.zeros((C.DELAY_HIST_BINS,))
    for i in (1, 2, 10, C.DELAY_HIST_BINS - 1):
        edge = S.DELAY_BIN_EDGES_US[i]
        h = np.asarray(S._delay_hist_add(h0, jnp.array([edge]),
                                         jnp.array([1.0])))
        assert h[i] == 1.0, (i, edge, np.nonzero(h))
    # below MIN -> bin 0; beyond the last edge -> clipped into last bin
    h = np.asarray(S._delay_hist_add(h0, jnp.array([0.5]),
                                     jnp.array([1.0])))
    assert h[0] == 1.0
    h = np.asarray(S._delay_hist_add(
        h0, jnp.array([S.DELAY_BIN_EDGES_US[-1] * 100]),
        jnp.array([1.0])))
    assert h[-1] == 1.0


def test_attribution_identity(delay_results):
    """The sampled mean decomposes exactly into fixed path cost +
    queueing + wake stalls + fault stalls (the split _finalize
    reports; the fault term is exactly 0 here — zero fault knobs)."""
    res, _ = delay_results
    for r in res:
        base = S.STACK_US + 4.0 * S.WIRE_HOP_US \
            + 2.0 * S.WIRE_HOP_US * r["delay_frac_inter"]
        total = base + r["delay_queue_us"] + r["delay_wake_stall_us"] \
            + r["delay_fault_stall_us"]
        assert r["delay_fault_stall_us"] == 0.0
        assert abs(total - r["delay_mean_sampled_us"]) \
            <= 1e-5 * max(total, 1.0), r["label"]


def test_wake_stall_zero_without_gating(delay_results):
    """With gating disabled no stage-up ever fires: the wake-stall
    attribution is EXACTLY zero (the acceptance bar, not approximately)."""
    res, _ = delay_results
    base = next(r for r in res if not r["gating"])
    assert base["delay_wake_stall_us"] == 0.0
    assert base["wake_stall_frac"] == 0.0


def test_wake_stall_positive_under_gating(delay_results):
    """A loaded LC/DC scenario pays real stage-up stalls, and they are
    visible in the attribution split."""
    res, _ = delay_results
    lc = next(r for r in res if r["gating"])
    assert lc["delay_wake_stall_us"] > 0.0
    assert 0.0 < lc["wake_stall_frac"] < 1.0
    # the penalty the stalls cause: gated delay tail at or above baseline
    basef = next(r for r in res if not r["gating"])
    assert lc["delay_p50_us"] >= basef["delay_p50_us"] - 1e-6


def test_hist_chunk_fold_invariant():
    """The histogram is an ordinary accumulator: folding it into float64
    at chunk boundaries (with a masked remainder tail) must not change a
    single bin."""
    batch = S.sweep_grid(traces=("university",), gating=(True,))
    whole = S.run_sweep(batch, 1_000, chunk_ticks=10_000)[0]
    remainder = S.run_sweep(batch, 1_000, chunk_ticks=300)[0]
    np.testing.assert_allclose(np.asarray(whole["delay_hist"]),
                               np.asarray(remainder["delay_hist"]),
                               atol=1e-9)
    for k in ("delay_p50_us", "delay_p99_us", "delay_queue_us",
              "delay_wake_stall_us", "wake_stall_frac"):
        assert abs(whole[k] - remainder[k]) <= 1e-6 * max(
            abs(whole[k]), 1.0), k


def test_occupancy_moments_sane(delay_results):
    res, _ = delay_results
    for r in res:
        for tier in ("rsw", "csw"):
            mean = r[f"{tier}_occ_mean_pkts"]
            var = r[f"{tier}_occ_var_pkts"]
            assert mean >= 0.0 and var >= 0.0
            # per-port backlog is capped at queue_cap
            assert mean <= C.QUEUE_CAP_PKTS


# ---- on_frac_hist boundary semantics (satellite bugfix) ----------------

def test_on_frac_bucket_boundaries():
    """Half-open-left quartiles (0,25],(25,50],(50,75],(75,100]: exact
    boundaries belong to the LOWER bucket; 0 clips into the first bucket
    and 100% into the last (no phantom 5th bucket)."""
    frac = jnp.array([0.0, 0.1, 0.25, 0.25 + 1e-6, 0.5, 0.5 + 1e-6,
                      0.75, 0.75 + 1e-6, 1.0])
    expect = np.array([0, 0, 0, 1, 1, 2, 2, 3, 3])
    np.testing.assert_array_equal(
        np.asarray(S.on_frac_bucket(frac)), expect)


def test_all_floor_state_is_first_bucket():
    """The common all-idle state (every switch at stage 1 of 4) is
    exactly 25% on and must be counted in the 0-25 bucket — the bug this
    PR fixes put it in 25-50."""
    assert int(S.on_frac_bucket(jnp.float32(144.0 / 576.0))) == 0


# ---- hull-padding power-accounting regression (satellite audit) --------

def test_padded_column_site_identical_activation():
    """A site padded along the PLANE/UPLINK columns (csw_per_cluster and
    n_fc smaller than the hull's) must report exactly the activation
    metrics of its unpadded twin: powered columns beyond the real link
    count must never light up, and frac_on normalizes by the real site."""
    small = FBSite(n_clusters=2, racks_per_cluster=4, servers_per_rack=8,
                   csw_per_cluster=2, n_fc=2, csw_ring_links=4,
                   fc_ring_links=8)
    wide = FBSite(n_clusters=2, racks_per_cluster=4, servers_per_rack=8,
                  csw_per_cluster=4, n_fc=4, csw_ring_links=4,
                  fc_ring_links=8)
    spec = TRAFFIC_SPECS["fb_hadoop"]
    run = (S.SimParams(spec=spec, site=small, rate_scale=1.5), 0)
    alone = S.run_sweep(S.make_batch([run]), 1_500)[0]
    padded = S.run_sweep(S.make_multi_site_batch(
        [run, (S.SimParams(spec=spec, site=wide), 1)]), 1_500)[0]
    # a real column-masking bug (padded columns counted as powered, or
    # frac_on normalized by hull dims) shifts EVERY tick's frac_on, i.e.
    # O(1) divergence; the tolerance only forgives a couple of ticks
    # flipped by backend-dependent f32 reduction order over the padded
    # (differently-shaped) arrays — 2e-3 of 1500 ticks = 3 ticks
    np.testing.assert_allclose(np.asarray(alone["on_frac_hist"]),
                               np.asarray(padded["on_frac_hist"]),
                               atol=2e-3)
    for k in ("half_off_frac", "rsw_link_on_frac", "csw_link_on_frac"):
        assert abs(alone[k] - padded[k]) <= 2e-3, (k, alone[k], padded[k])
