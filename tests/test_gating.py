"""Watermark stage-controller unit + property tests."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.core import gating


def steps(state, queues, n, **kw):
    for _ in range(n):
        state = gating.gate_step(state, queues, **kw)
    return state


def test_initial_state_stage_one():
    s = gating.gate_init(4, 4)
    assert np.all(np.asarray(s.stage) == 1)
    assert np.asarray(s.powered).sum() == 4          # one link each


def test_stage_up_on_high_watermark():
    s = gating.gate_init(1, 4)
    hot = jnp.array([[19.0, 0, 0, 0]])
    s = steps(s, hot, 5, cap=20, up_delay=2)
    # sustained load over the watermark keeps raising stages
    assert 2 <= int(s.stage[0]) <= 3
    # the rising/active links were charged as powered
    assert bool(s.powered[0, 1])


def test_stage_down_after_drain_and_dwell():
    s = gating.gate_init(1, 4)
    s = s._replace(stage=jnp.array([3], jnp.int32))
    idle = jnp.zeros((1, 4))
    s = steps(s, idle, 80, cap=20, dwell=0, off_delay=5)
    assert int(s.stage[0]) == 1                      # drained back to floor


def test_never_below_stage_one():
    s = gating.gate_init(8, 4)
    idle = jnp.zeros((8, 4))
    s = steps(s, idle, 200, dwell=0)
    assert np.all(np.asarray(s.stage) >= 1)
    assert np.all(np.asarray(s.powered)[:, 0])       # stage-1 link stays on


def test_dwell_blocks_flap():
    s = gating.gate_init(1, 4)
    hot = jnp.array([[19.0, 0, 0, 0]])
    s = steps(s, hot, 4, cap=20, up_delay=2, dwell=100)
    lvl = int(s.stage[0])
    assert lvl >= 2
    idle = jnp.zeros((1, 4))
    s2 = steps(s, idle, 20, cap=20, dwell=100)
    assert int(s2.stage[0]) == lvl                   # held by dwell
    s3 = steps(s, idle, 400, cap=20, dwell=100)
    assert int(s3.stage[0]) == 1                     # released after dwell


def test_off_transition_charged():
    s = gating.gate_init(1, 2)
    s = s._replace(stage=jnp.array([2], jnp.int32))
    idle = jnp.zeros((1, 2))
    s = steps(s, idle, 3, dwell=0, off_delay=10)
    # stage already dropped but the link is still charged (off transition)
    assert int(s.stage[0]) == 1
    assert bool(s.powered[0, 1])
    s = steps(s, idle, 12, dwell=0, off_delay=10)
    assert not bool(s.powered[0, 1])


@given(st.lists(st.floats(0, 20), min_size=4, max_size=4),
       st.integers(1, 4))
def test_property_connectivity_and_power_superset(qs, stage0):
    """Invariants: stage in [1, L]; powered >= active links; link 0 on."""
    s = gating.gate_init(1, 4)._replace(
        stage=jnp.array([stage0], jnp.int32))
    q = jnp.array([qs])
    for _ in range(5):
        s = gating.gate_step(s, q, cap=20)
        st_ = int(s.stage[0])
        assert 1 <= st_ <= 4
        powered = np.asarray(s.powered)[0]
        active = np.arange(4) < st_
        drain_top = bool(s.draining[0])
        usable = np.asarray(gating.active_mask(s, 4))[0]
        # every usable link is powered
        assert np.all(~usable | powered)
        assert powered[0]


def test_max_stage_caps_per_switch():
    """Per-switch max_stage (the multi-site real-link ceiling): a padded
    switch never activates links beyond its site's own link count."""
    s = gating.gate_init(3, 4)
    hot = jnp.full((3, 4), 19.0)
    cap = jnp.array([1, 2, 4], jnp.int32)
    for _ in range(60):
        s = gating.gate_step(s, hot, cap=20, up_delay=1, max_stage=cap)
        assert np.all(np.asarray(s.stage) <= np.asarray(cap))
    np.testing.assert_array_equal(np.asarray(s.stage), np.asarray(cap))


@given(st.integers(0, 3))
def test_property_monotone_under_sustained_load(seed):
    """Sustained saturation drives the stage to max and keeps it there."""
    rng = np.random.default_rng(seed)
    s = gating.gate_init(2, 4)
    for _ in range(60):
        q = jnp.asarray(rng.uniform(16, 20, size=(2, 4)))
        prev = np.asarray(s.stage).copy()
        s = gating.gate_step(s, q, cap=20, up_delay=1)
        assert np.all(np.asarray(s.stage) >= prev)   # never down under load
    assert np.all(np.asarray(s.stage) == 4)
