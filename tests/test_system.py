"""End-to-end behaviour of the whole system (CPU, tiny configs)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step


def test_end_to_end_train_then_serve():
    """Train a tiny dense LM for 30 steps on structured data, then serve
    greedily from a prefill cache: loss falls and decode runs."""
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), vocab=256)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt_init, _ = make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3))
    losses = []
    for i in range(30):
        params, opt, m = step(params, opt, batch_at(data, i),
                              jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])

    # serve: prefill 16 tokens, decode 8 more greedily
    prompt = batch_at(data, 999)["tokens"][:2, :16]
    logits, cache = M.prefill(cfg, params, {"tokens": prompt})
    cache_full = M.init_cache(cfg, 2, 24, dtype=cfg.dtype)
    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                sl = [slice(None)] * dst.ndim
                sl[ax] = slice(0, src.shape[ax])
                return dst.at[tuple(sl)].set(src)
        return src
    cache = jax.tree.map(merge, cache_full, cache)
    tok = jnp.argmax(logits, -1)[:, None]
    outs = []
    dec = jax.jit(lambda p, c, t, po: M.decode_step(cfg, p, c, t, po))
    for t in range(16, 24):
        logits, cache = dec(params, cache, tok,
                            jnp.full((2,), t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        outs.append(tok)
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert len(outs) == 8


def test_paper_validation_headline_numbers():
    """The headline LC/DC claims hold in short runs: avg switch-tier
    savings near 60%, latency penalty < 20%, >= half network off most of
    the time (paper: 60% avg / 68% max savings, +6% delay, 87% half-off)."""
    from repro.core.simulator import SimParams, run_sim
    from repro.core.traffic import TRAFFIC_SPECS
    saves, pens, half = [], [], []
    for name in ["fb_hadoop", "university", "microsoft"]:
        lc = run_sim(SimParams(spec=TRAFFIC_SPECS[name]), 10_000, seed=0)
        base = run_sim(SimParams(spec=TRAFFIC_SPECS[name],
                                 gating_enabled=False), 10_000, seed=0)
        saves.append(lc["switch_energy_savings_frac"])
        pens.append(lc["mean_latency_us"] / base["mean_latency_us"] - 1)
        half.append(lc["half_off_frac"])
    assert 0.40 <= float(np.mean(saves)) <= 0.75, saves
    assert float(np.mean(pens)) <= 0.25, pens
    assert float(np.mean(half)) >= 0.5, half
