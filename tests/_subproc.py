"""Helper to run a python snippet in a subprocess with N fake devices."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n"
            f"{res.stderr[-4000:]}")
    return res.stdout
