"""Scenario-axis sharding (4 fake CPU devices via subprocess):
sharded-vs-unsharded parity, devices-multiple batch padding inertness,
the one-host-transfer contract under sharding, and the sharded planned
(async bucket) path."""
import json

import pytest

from tests._subproc import run_with_devices

# one subprocess runs every check: jax startup dominates, so amortize it
_CODE = """
import json
import jax
from repro.core import simulator as S
from repro.core.traffic import TRAFFIC_SPECS
from repro.core.topology import FBSite

assert jax.local_device_count() == 4, jax.local_device_count()
out = {}

def worst(a, b):
    return S.worst_parity(a, b)[0]

# --- B=6 (pads to 8 over 4 devices: 2 inert pad rows) ------------------
runs = [(S.SimParams(spec=TRAFFIC_SPECS["fb_hadoop"], gating_enabled=g), s)
        for g in (True, False) for s in (0, 1, 2)]
batch = S.make_batch(runs)
h0 = S.HOST_TRANSFER_COUNT
sharded = S.run_sweep(batch, 700, chunk_ticks=300)        # auto-sharded
out["sharded_transfers"] = S.HOST_TRANSFER_COUNT - h0
unsharded = S.run_sweep(batch, 700, chunk_ticks=300, shard=False)
out["pad_parity"] = worst(unsharded, sharded)
out["n_results"] = len(sharded)
out["labels_match"] = [r["label"] for r in sharded] == list(batch.labels)

# --- B=8 (divisible: pure sharding, no padding) ------------------------
batch8 = S.make_batch(runs + [(runs[0][0], 7), (runs[3][0], 7)])
out["nopad_parity"] = worst(
    S.run_sweep(batch8, 500, chunk_ticks=250, shard=False),
    S.run_sweep(batch8, 500, chunk_ticks=250, shard=True))

# --- return_state drops the pad rows -----------------------------------
_, st = S.run_sweep(batch, 300, return_state=True)
out["state_rows"] = int(st.rsw_q.shape[0])

# --- planned async path, sharded: per-bucket contracts still hold ------
mixed = [(S.SimParams(spec=TRAFFIC_SPECS["fb_hadoop"], site=FBSite(
              n_clusters=2, racks_per_cluster=4, servers_per_rack=8,
              csw_per_cluster=2, n_fc=2, csw_ring_links=4,
              fc_ring_links=8), gating_enabled=g), s)
         for g in (True, False) for s in (0, 1)] + \
        [(S.SimParams(spec=TRAFFIC_SPECS["university"]), s)
         for s in (0, 1)]
n0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
planned, plan = S.run_sweep_planned(mixed, 500, chunk_ticks=200,
                                    max_compiles=2, return_plan=True)
out["planned_traces"] = S.TRACE_COUNT - n0
out["planned_transfers"] = S.HOST_TRANSFER_COUNT - h0
out["planned_buckets"] = plan["n_buckets"]
out["planned_parity"] = worst(
    S.run_sweep_planned(mixed, 500, chunk_ticks=200, max_compiles=2,
                        shard=False), planned)
print("RESULT " + json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_out():
    stdout = run_with_devices(_CODE, n_devices=4)
    line = [ln for ln in stdout.splitlines() if ln.startswith("RESULT ")]
    assert line, stdout
    return json.loads(line[-1][len("RESULT "):])


def test_sharded_matches_unsharded_with_padding(sharded_out):
    """B=6 padded to 8 over 4 devices: every real scenario's metrics
    match the single-device run — scenarios are independent vmap lanes,
    so sharding + pad rows are inert (<= 1e-6; bitwise in practice)."""
    assert sharded_out["pad_parity"] <= 1e-6
    assert sharded_out["n_results"] == 6
    assert sharded_out["labels_match"]


def test_sharded_matches_unsharded_divisible(sharded_out):
    """B=8 over 4 devices (no padding): pure layout change, same
    metrics."""
    assert sharded_out["nopad_parity"] <= 1e-6


def test_sharded_run_is_one_host_transfer(sharded_out):
    """Sharding must not reintroduce per-chunk synchronization: the
    device fold still fetches exactly once."""
    assert sharded_out["sharded_transfers"] == 1


def test_sharded_return_state_drops_pad_rows(sharded_out):
    assert sharded_out["state_rows"] == 6


def test_sharded_planned_contracts(sharded_out):
    """The async-pipelined planner under sharding: one trace and one
    fold fetch per hull bucket, metrics matching the unsharded planned
    run."""
    assert sharded_out["planned_buckets"] == 2
    assert sharded_out["planned_traces"] == 2
    assert sharded_out["planned_transfers"] == 2
    assert sharded_out["planned_parity"] <= 1e-6
