"""Assigned-architecture configs match the spec table exactly."""
import pytest

from repro.configs import ARCH_IDS, REGISTRY, SHAPES, cells_for, get_config

SPEC = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "kimi-k2-1t-a32b": (61, 7168, 64, 8, None, 163840),
    "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
    "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "granite-34b": (88, 6144, 48, 1, 24576, 49152),
    "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
    "hubert-xlarge": (48, 1280, 16, 16, 5120, 504),
    "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
}


def test_all_archs_present():
    assert set(ARCH_IDS) == set(SPEC)


@pytest.mark.parametrize("arch", list(SPEC))
def test_config_matches_spec(arch):
    L, d, H, kv, dff, vocab = SPEC[arch]
    c = get_config(arch)
    assert c.n_layers == L and c.d_model == d and c.vocab == vocab
    if H is not None and not c.attention_free:
        assert c.n_heads == H and c.n_kv == kv
    if dff is not None:
        assert c.d_ff == dff


def test_moe_setups():
    kimi = get_config("kimi-k2-1t-a32b")
    assert kimi.n_experts == 384 and kimi.top_k == 8 and kimi.d_expert == 2048
    assert kimi.first_dense == 1
    mix = get_config("mixtral-8x7b")
    assert mix.n_experts == 8 and mix.top_k == 2 and mix.swa_window == 4096
    jam = get_config("jamba-v0.1-52b")
    assert jam.n_experts == 16 and jam.top_k == 2
    # jamba layer pattern: attention at i % 8 == 4, moe at odd layers
    kinds = [jam.layer_kind(i) for i in range(8)]
    assert kinds == ["mamba"] * 4 + ["attn"] + ["mamba"] * 3
    assert jam.ffn_kind(1) == "moe" and jam.ffn_kind(2) == "mlp"


def test_param_counts_in_expected_range():
    """6ND sanity: the analytic parameter counts must land near the
    advertised model sizes."""
    expect = {
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "mixtral-8x7b": (42e9, 50e9),
        "qwen3-0.6b": (0.4e9, 0.9e9),
        "minicpm3-4b": (3e9, 5e9),
        "granite-34b": (30e9, 38e9),
        "qwen3-8b": (7e9, 10e9),
        "rwkv6-7b": (6e9, 9e9),
        "jamba-v0.1-52b": (45e9, 58e9),
        "internvl2-76b": (62e9, 80e9),   # LLM backbone of the 76B VLM
        "hubert-xlarge": (0.8e9, 1.2e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).n_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_active_params_kimi():
    c = get_config("kimi-k2-1t-a32b")
    act = c.n_active_params()
    assert 20e9 <= act <= 45e9           # "a32b"
    assert act < c.n_params() / 10


def test_cell_skip_rules():
    total_run, total_skip = 0, 0
    for arch in ARCH_IDS:
        for cell in cells_for(get_config(arch)):
            total_run += cell.run
            total_skip += not cell.run
            if not cell.run:
                assert cell.skip_reason
    assert total_run + total_skip == 40           # 10 archs x 4 shapes
    assert total_run == 32                        # per DESIGN.md
    # hubert has no decode; full-attention archs skip long_500k
    hub = {c.shape.name: c.run for c in cells_for(get_config("hubert-xlarge"))}
    assert not hub["decode_32k"] and not hub["long_500k"]
    mix = {c.shape.name: c.run for c in cells_for(get_config("mixtral-8x7b"))}
    assert mix["long_500k"]                       # SWA is sub-quadratic
    q8 = {c.shape.name: c.run for c in cells_for(get_config("qwen3-8b"))}
    assert not q8["long_500k"]


def test_padded_vocab_divisible():
    for arch in ARCH_IDS:
        c = get_config(arch)
        assert c.padded_vocab % 512 == 0
        assert c.padded_vocab >= c.vocab


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].kind == "decode"
    assert SHAPES["long_500k"].seq_len == 524288
