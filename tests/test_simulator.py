"""Network-simulator behaviour tests (short runs)."""
import dataclasses

import numpy as np
import pytest

from repro.core.simulator import SimParams, run_sim
from repro.core.traffic import TRAFFIC_SPECS

TICKS = 8_000


@pytest.fixture(scope="module")
def hadoop_results():
    lc = run_sim(SimParams(spec=TRAFFIC_SPECS["fb_hadoop"]), TICKS, seed=0)
    base = run_sim(SimParams(spec=TRAFFIC_SPECS["fb_hadoop"],
                             gating_enabled=False), TICKS, seed=0)
    return lc, base


def test_baseline_has_no_savings(hadoop_results):
    _, base = hadoop_results
    assert base["switch_energy_savings_frac"] == 0.0
    assert base["rsw_link_on_frac"] == 1.0


def test_gating_saves_energy(hadoop_results):
    lc, _ = hadoop_results
    assert 0.30 <= lc["switch_energy_savings_frac"] <= 0.75
    # stage 1 is never gated: on-fraction >= 25%
    assert lc["rsw_link_on_frac"] >= 0.25 - 1e-9
    assert lc["csw_link_on_frac"] >= 0.25 - 1e-9


def test_latency_penalty_bounded(hadoop_results):
    lc, base = hadoop_results
    pen = lc["mean_latency_us"] / base["mean_latency_us"] - 1.0
    assert -0.05 <= pen <= 0.60, pen
    assert lc["mean_latency_us"] >= 3.75      # >= the TCP stack alone


def test_packet_conservation(hadoop_results):
    lc, _ = hadoop_results
    # delivered + drops cannot exceed injected; most packets delivered
    assert lc["delivered_pkts"] <= lc["injected_pkts"] * 1.001
    assert lc["delivered_pkts"] >= lc["injected_pkts"] * 0.80
    assert lc["drop_frac"] < 0.05


def test_on_frac_histogram_normalized(hadoop_results):
    lc, _ = hadoop_results
    assert abs(sum(lc["on_frac_hist"]) - 1.0) < 1e-6


def test_determinism():
    p = SimParams(spec=TRAFFIC_SPECS["university"])
    a = run_sim(p, 2_000, seed=42)
    b = run_sim(p, 2_000, seed=42)
    assert a["injected_pkts"] == b["injected_pkts"]
    assert a["switch_energy_savings_frac"] == b["switch_energy_savings_frac"]


def test_rate_scale_monotone():
    """More offered load -> more links on (less savings)."""
    spec = TRAFFIC_SPECS["microsoft"]
    lo = run_sim(SimParams(spec=spec, rate_scale=0.3), 6_000, seed=1)
    hi = run_sim(SimParams(spec=spec, rate_scale=1.5), 6_000, seed=1)
    assert hi["rsw_link_on_frac"] >= lo["rsw_link_on_frac"] - 0.02
    assert hi["injected_pkts"] > lo["injected_pkts"]
