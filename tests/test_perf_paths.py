"""Correctness of the hillclimb-winning execution paths:

  * gqa_decode_sp (shard_map flash-decode, EXPERIMENTS.md Cell C)
  * microbatched gradient accumulation (Cell A fit lever)
  * psum_scatter MoE combine (Cell A iteration 1)
  * ZeRO-2 optimizer-state sharding specs (Cell B iteration 3)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step
from tests._subproc import run_with_devices


def test_microbatched_step_matches_plain():
    """k-microbatch grad accumulation == one big batch (same tokens)."""
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), vocab=128)
    cfg_mb = dataclasses.replace(cfg, microbatches=4)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt_init, _ = make_optimizer(cfg)
    batch = {"tokens": jax.random.randint(key, (8, 16), 0, cfg.vocab),
             "targets": jax.random.randint(key, (8, 16), 0, cfg.vocab)}
    p1, _, m1 = jax.jit(make_train_step(cfg))(
        params, opt_init(params), batch, jnp.zeros((), jnp.int32))
    p2, _, m2 = jax.jit(make_train_step(cfg_mb))(
        params, opt_init(params), batch, jnp.zeros((), jnp.int32))
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    d = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3, d


@pytest.mark.slow
def test_decode_sp_matches_plain_decode():
    out = run_with_devices("""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import model as M
from repro.launch.mesh import make_test_mesh, dist_for, set_mesh

cfg0 = reduced(get_config("qwen3-8b"))
mesh = make_test_mesh(2, 2)
dist = dist_for(mesh)
key = jax.random.PRNGKey(0)
params = M.init_params(cfg0, key)
B, T = 4, 12
toks = jax.random.randint(key, (B, T), 0, cfg0.vocab)
logits_full, _ = M.prefill(cfg0, params, {"tokens": toks})
_, cache = M.prefill(cfg0, params, {"tokens": toks[:, :-1]})
cache_full = M.init_cache(cfg0, B, T, dtype=cfg0.dtype)
def merge(dst, src):
    if dst.shape == src.shape: return src
    for ax in range(dst.ndim):
        if dst.shape[ax] != src.shape[ax]:
            sl = [slice(None)]*dst.ndim; sl[ax] = slice(0, src.shape[ax])
            return dst.at[tuple(sl)].set(src)
    return src
cache = jax.tree.map(merge, cache_full, cache)
pos = jnp.full((B,), T-1, jnp.int32)
cfg_sp = dataclasses.replace(cfg0, decode_sp=True)
with set_mesh(mesh):
    logits_sp, c2 = jax.jit(lambda p, c, t, po: M.decode_step(
        cfg_sp, p, c, t, po, dist))(params, cache, toks[:, -1:], pos)
err = float(jnp.max(jnp.abs(logits_sp - logits_full)))
assert err < 3e-3, err
# cache roundtrip types preserved
for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(c2)):
    assert a.shape == b.shape and a.dtype == b.dtype
print("OK decode_sp", err)
""")
    assert "OK decode_sp" in out


@pytest.mark.slow
def test_moe_psum_scatter_combine_matches():
    out = run_with_devices("""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config, reduced
from repro.models import moe as moe_mod
from repro.launch.mesh import make_test_mesh, dist_for, set_mesh

cfg = dataclasses.replace(reduced(get_config("jamba-v0.1-52b")),
                          capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = moe_mod.moe_init(key, cfg, jnp.float32)
x = jax.random.normal(key, (4, 8, cfg.d_model))
y_ref, _ = moe_mod.moe_apply_pure(p, cfg, x)
mesh = make_test_mesh(2, 2)
dist = dist_for(mesh)
cfg_ps = dataclasses.replace(cfg, moe_combine="psum_scatter")
with set_mesh(mesh):
    y_ps, _ = jax.jit(
        lambda p, x: moe_mod.moe_apply_dist(p, cfg_ps, x, dist))(p, x)
err = float(jnp.max(jnp.abs(y_ref - y_ps)))
assert err < 2e-4, err
print("OK psum_scatter", err, moe_mod.ep_mode(cfg, dist))
""")
    assert "OK psum_scatter" in out


def test_zero2_specs_shard_moments_not_params():
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import opt_extra_shard, param_specs
    from repro.launch.mesh import DistContext

    cfg = dataclasses.replace(get_config("granite-34b"), zero=2)

    class FakeMesh:
        shape = {"data": 16, "model": 16}
    dist = DistContext(mesh=FakeMesh(), data_axes=("data",),
                       model_axis="model")
    specs, shapes = param_specs(cfg, dist)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    # ZeRO-2: no param spec mentions 'data'
    assert not any("data" in str(s) for s in flat_s)
    # moments DO get a data axis where divisible
    sp = opt_extra_shard(cfg, dist, P(None, "model"),
                         jax.ShapeDtypeStruct((6144, 24576), jnp.float32))
    assert "data" in str(sp)
