"""Optical fault-injection subsystem: knob validation, zero-rate
inertness, conservation with the fault-drop bin, the connectivity-
preserving fallback contract (hypothesis property + full-sim audit),
correlated whole-plane failure domains (plane_fail_prob), the
fault-tolerant planned executor, and the opt-in validate mode."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import constants as C
from repro.core import gating
from repro.core import simulator as S
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

TICKS = 2_000
# small-but-real site: two clusters so inter traffic exercises the CSW/FC
# tiers, same shape the fault frontier bench smokes
SITE = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
              csw_per_cluster=2, n_fc=2, csw_ring_links=4, fc_ring_links=8)
# harsh enough that every fault mechanism fires within TICKS
HARSH = dict(wake_fail_prob=0.30, wake_jitter_frac=0.50,
             link_mtbf_ticks=5_000.0, repair_ticks=400)


def _params(**kw):
    # rate_scale 1.6 keeps the stage churning so wake events (the thing
    # the fail/jitter knobs act on) actually occur
    kw.setdefault("rate_scale", 1.6)
    return S.SimParams(spec=TRAFFIC_SPECS["fb_hadoop"], site=SITE, **kw)


@pytest.fixture(scope="module")
def fault_results():
    """One sweep over the four canonical fault modes (zero-knob LC/DC,
    harsh LC/DC with and without the fallback, harsh always-on), with
    the final state for the conservation audit."""
    rows = {
        "zero": _params(),
        "fallback": _params(**HARSH),
        "nofb": _params(**HARSH, fault_fallback=False),
        "base": _params(**HARSH, gating_enabled=False),
        # plane faults ONLY (no per-link MTBF): any link fault observed
        # in this row came through the correlated-plane mechanism
        "plane": _params(plane_fail_prob=5e-3, repair_ticks=200),
    }
    batch = S.make_batch([(p, 8 + i) for i, p in enumerate(rows.values())])
    res, state = S.run_sweep(batch, TICKS, chunk_ticks=500,
                             return_state=True)
    return dict(zip(rows, res)), state


# ---- knob validation (satellite a) --------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(rate_scale=-0.5), "rate_scale"),
    (dict(queue_cap=0.0), "queue_cap"),
    (dict(hi=0.3, lo=0.5), "inverted watermarks"),
    (dict(wake_fail_prob=1.0), "wake_fail_prob"),
    (dict(wake_fail_prob=-0.1), "wake_fail_prob"),
    (dict(wake_jitter_frac=1.5), "wake_jitter_frac"),
    (dict(link_mtbf_ticks=-1.0), "link_mtbf_ticks"),
    (dict(link_mtbf_ticks=0.5), "link_mtbf_ticks"),
    (dict(repair_ticks=-1), "repair_ticks"),
    (dict(link_mtbf_ticks=100.0, repair_ticks=0), "repair_ticks"),
    (dict(plane_fail_prob=1.0), "plane_fail_prob"),
    (dict(plane_fail_prob=-0.1), "plane_fail_prob"),
    (dict(plane_fail_prob=0.001), "repair_ticks"),
])
def test_simparams_rejects_bad_knobs(kw, match):
    with pytest.raises(ValueError, match=match):
        _params(**kw)


def test_zero_ticks_rejected():
    batch = S.make_batch([(_params(), 0)])
    with pytest.raises(ValueError, match="n_ticks must be >= 1"):
        S.run_sweep(batch, 0)


# ---- zero-rate inertness ------------------------------------------------

def test_zero_knobs_fault_metrics_exactly_zero(fault_results):
    res, _ = fault_results
    r = res["zero"]
    for k in ("fault_drop_frac", "fault_dropped_pkts", "wake_retries",
              "forced_wakes", "conn_loss_ticks", "link_fault_frac",
              "delay_fault_stall_us", "fault_stall_frac"):
        assert r[k] == 0.0, k


def test_gate_step_zero_rate_bit_parity():
    """Fault-mode gate_step with zero knobs and all-healthy links is
    bit-identical to the legacy fault-free path, tick by tick."""
    rng = np.random.default_rng(3)
    Ssw, L = 6, 4
    legacy = fault = gating.gate_init(Ssw, L)
    fwake = jnp.zeros((Ssw,), jnp.int32)
    ones = jnp.ones((Ssw, L), bool)
    for _ in range(40):
        q = jnp.asarray(
            rng.uniform(0, C.QUEUE_CAP_PKTS, (Ssw, L)), jnp.float32)
        legacy = gating.gate_step(legacy, q)
        fault, fwake, diag = gating.gate_step(
            fault, q, link_ok=ones, link_real=ones,
            u_jitter=jnp.asarray(rng.random(Ssw), jnp.float32),
            u_fail=jnp.asarray(rng.random(Ssw), jnp.float32),
            fault_wake=fwake)
        for a, b in zip(legacy, fault):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.any(np.asarray(diag["retries"]))
        assert not np.any(np.asarray(diag["forced"]))
        assert not np.any(np.asarray(fwake))


# ---- conservation under faults (satellite c) ----------------------------

def test_conservation_with_fault_drops(fault_results):
    """injected == delivered + queue-drops + fault-drops + in-flight,
    exactly (f32 fold noise only), in EVERY fault mode."""
    res, state = fault_results
    for i, (mode, r) in enumerate(res.items()):
        in_flight = sum(
            float(np.sum(np.asarray(q)[i]))
            for q in (state.rsw_q, state.csw_up_q, state.csw_down_q,
                      state.fc_down_q))
        inj = r["injected_pkts"]
        acct = (r["delivered_pkts"] + r["drop_frac"] * inj
                + r["fault_dropped_pkts"] + in_flight)
        assert abs(inj - acct) <= 1e-3 * max(inj, 1.0), (mode, inj, acct)


def test_fault_mechanisms_actually_fire(fault_results):
    """The harsh knobs exercise every mechanism (guards against a test
    that passes vacuously because faults never happened)."""
    res, _ = fault_results
    harsh = res["fallback"]
    assert harsh["link_fault_frac"] > 0.0
    assert harsh["wake_retries"] + harsh["forced_wakes"] > 0.0
    assert harsh["delivered_frac"] > 0.5  # degraded but not collapsed
    assert res["nofb"]["wake_retries"] > 0.0


# ---- correlated failure domains (plane_fail_prob) -----------------------

def test_fault_arrivals_whole_plane_correlation():
    """A plane draw under the hazard takes EVERY healthy powered real
    link of that plane down in the same tick; planes whose draw clears
    it lose none (the per-link stream is silenced here: u == 1 never
    fires under strict <)."""
    Ssw, L = 3, 4
    timer = jnp.zeros((Ssw, L), jnp.int32)
    ones = jnp.ones((Ssw, L), bool)
    u = jnp.ones((Ssw, L), jnp.float32)
    plane_u = jnp.broadcast_to(
        jnp.asarray([[0.0], [0.009], [0.5]], jnp.float32), (Ssw, L))
    timer2, fault = gating.fault_arrivals(
        timer, u, ones, ones, 0.0, 7, plane_u=plane_u,
        plane_fail_prob=0.01)
    np.testing.assert_array_equal(
        np.asarray(fault),
        np.asarray([[True] * L, [True] * L, [False] * L]))
    assert np.all(np.asarray(timer2)[:2] == 7)
    assert np.all(np.asarray(timer2)[2] == 0)


def test_fault_arrivals_plane_zero_rate_bit_inert():
    """plane_fail_prob == 0 is STRUCTURALLY inert: even an all-zero
    plane_u field (the worst case for an epsilon-based gate — uniforms
    are >= 0 and the compare is strict <) yields bit-identical outputs
    to the no-plane-argument call."""
    rng = np.random.default_rng(7)
    timer = jnp.asarray(rng.integers(0, 3, (4, 4)), jnp.int32)
    u = jnp.asarray(rng.random((4, 4)), jnp.float32)
    powered = jnp.asarray(rng.random((4, 4)) < 0.7)
    real = jnp.asarray(rng.random((4, 4)) < 0.9)
    plane_u = jnp.zeros((4, 4), jnp.float32)
    a = gating.fault_arrivals(timer, u, powered, real, 0.05, 9)
    b = gating.fault_arrivals(timer, u, powered, real, 0.05, 9,
                              plane_u=plane_u, plane_fail_prob=0.0)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_plane_faults_fire_in_full_sim(fault_results):
    """With per-link MTBF OFF, every observed link fault came through
    the correlated-plane mechanism — and the fabric degrades rather
    than collapses."""
    res, _ = fault_results
    plane = res["plane"]
    assert plane["link_fault_frac"] > 0.0
    assert plane["delivered_frac"] > 0.5


# ---- connectivity contract ----------------------------------------------

def test_fallback_no_avoidable_connectivity_loss(fault_results):
    """With the fallback, a switch that still has a healthy real link
    NEVER sits with zero usable links — the audit is exactly 0."""
    res, _ = fault_results
    assert res["fallback"]["conn_loss_ticks"] == 0.0


def test_no_fallback_loses_connectivity(fault_results):
    res, _ = fault_results
    assert res["nofb"]["conn_loss_ticks"] > 0.0


def test_gating_disabled_fault_stall_exactly_zero(fault_results):
    """Always-on links never wake, so the wake-fail/jitter knobs and
    the fallback have nothing to act on: those bins are EXACTLY 0 even
    under harsh knobs (hard faults still drop packets)."""
    res, _ = fault_results
    base = res["base"]
    assert base["fault_stall_frac"] == 0.0
    assert base["delay_fault_stall_us"] == 0.0
    assert base["wake_retries"] == 0.0
    assert base["forced_wakes"] == 0.0
    assert base["conn_loss_ticks"] == 0.0
    assert base["link_fault_frac"] > 0.0  # hard faults still strike


@given(st.integers(0, 2**31 - 1))
def test_fallback_min_connectivity_property(seed):
    """Hypothesis property: under RANDOM gate/fault sequences the
    fallback-enabled controller always leaves every switch that has a
    healthy real link with at least one USABLE healthy link after the
    tick — the min-connectivity invariant the datapath relies on."""
    rng = np.random.default_rng(seed)
    Ssw, L = 4, 4
    n_real = rng.integers(1, L + 1, size=Ssw)
    link_real = np.arange(L)[None, :] < n_real[:, None]
    state = gating.gate_init(Ssw, L)
    fwake = jnp.zeros((Ssw,), jnp.int32)
    for _ in range(25):
        q = rng.uniform(0, C.QUEUE_CAP_PKTS, (Ssw, L)) * link_real
        # random hard-fault pattern; switches may lose EVERY real link
        # (the unavoidable case the invariant is conditioned on)
        ok = (rng.random((Ssw, L)) > 0.4) & link_real
        link_ok = jnp.asarray(ok)
        state, fwake, _ = gating.gate_step(
            state, jnp.asarray(q, jnp.float32),
            max_stage=jnp.asarray(n_real, jnp.int32),
            link_ok=link_ok, link_real=jnp.asarray(link_real),
            u_jitter=jnp.asarray(rng.random(Ssw), jnp.float32),
            u_fail=jnp.asarray(rng.random(Ssw), jnp.float32),
            wake_fail_prob=0.3, wake_jitter_frac=0.5,
            fault_wake=fwake, fallback=True)
        usable_ok = np.asarray(
            gating.usable_links(state.stage, state.draining, L) & link_ok)
        has_ok = ok.any(axis=1)
        assert np.all(~has_ok | usable_ok.any(axis=1)), \
            (has_ok, usable_ok, np.asarray(state.stage))


def test_no_fallback_can_strand_a_switch():
    """The deterministic counterexample the fallback exists for: stage 1
    with link 0 hard-faulted leaves zero usable links without the
    fallback, and exactly one (the cheapest healthy link) with it."""
    state = gating.gate_init(1, 4)
    q = jnp.zeros((1, 4), jnp.float32)
    ok = jnp.asarray([[False, True, True, True]])
    ones = jnp.ones((1, 4), bool)
    kw = dict(link_ok=ok, link_real=ones,
              u_jitter=jnp.zeros((1,)), u_fail=jnp.ones((1,)),
              fault_wake=jnp.zeros((1,), jnp.int32))
    stranded, _, d0 = gating.gate_step(state, q, fallback=False, **kw)
    saved, fwake, d1 = gating.gate_step(state, q, fallback=True, **kw)
    usable = gating.usable_links(stranded.stage, stranded.draining, 4) & ok
    assert not np.any(np.asarray(usable))
    assert not np.any(np.asarray(d0["forced"]))
    usable = gating.usable_links(saved.stage, saved.draining, 4) & ok
    assert np.asarray(usable).sum() == 1 and np.asarray(usable)[0, 1]
    assert np.all(np.asarray(d1["forced"]))
    assert int(fwake[0]) > 0  # the force-wake's stall is charged


# ---- fault-tolerant planned executor ------------------------------------

def _two_bucket_runs():
    """Two distinct sites so the planner yields two hull buckets."""
    site_b = FBSite(n_clusters=2, racks_per_cluster=4, servers_per_rack=8,
                    csw_per_cluster=2, n_fc=2, csw_ring_links=4,
                    fc_ring_links=8)
    spec = TRAFFIC_SPECS["fb_hadoop"]
    return [(S.SimParams(spec=spec, site=SITE), 0),
            (S.SimParams(spec=spec, site=site_b), 1),
            (S.SimParams(spec=spec, site=SITE, gating_enabled=False), 2)]


def test_planned_sweep_isolates_permanent_bucket_failure():
    """A bucket that fails dispatch AND its serial retry comes back as
    structured error entries in caller order; the other bucket's runs
    complete untouched."""
    runs = _two_bucket_runs()
    calls = []

    def hook(k, phase):
        calls.append((k, phase))
        if k == 0:
            raise RuntimeError("boom retry")

    S.BUCKET_FAIL_HOOK = hook
    try:
        res = S.run_sweep_planned(runs, 600, max_compiles=2,
                                  chunk_ticks=300)
    finally:
        S.BUCKET_FAIL_HOOK = None
    assert len(res) == len(runs)
    good = [r for r in res if "error" not in r]
    bad = [r for r in res if "error" in r]
    assert good and bad
    # the original failure phase and the retry are both recorded
    for r in bad:
        assert r["error"] == {"type": "RuntimeError",
                              "message": "boom retry",
                              "stage": "dispatch", "retried": True}
        assert r["plan_bucket"] == 0
        assert r["label"] and r["plan_hull"]
    # caller order preserved: every entry matches its run's site/params
    for (p, seed), r in zip(runs, res):
        assert f"s{seed}" in r["label"]
    # the surviving bucket produced real metrics
    assert all(r["injected_pkts"] > 0 for r in good)
    assert (0, "retry") in calls


def test_planned_sweep_retry_succeeds_after_transient_failure():
    """A bucket that fails once at dispatch is retried serially (on the
    host-fold path) and succeeds: no error entries, caller order kept,
    and the hook sees dispatch -> retry -> next bucket."""
    runs = _two_bucket_runs()
    calls = []

    def hook(k, phase):
        calls.append((k, phase))
        if k == 0 and phase == "dispatch":
            raise RuntimeError("transient")

    S.BUCKET_FAIL_HOOK = hook
    try:
        res = S.run_sweep_planned(runs, 600, max_compiles=2,
                                  chunk_ticks=300, pipeline=False)
    finally:
        S.BUCKET_FAIL_HOOK = None
    assert all("error" not in r for r in res)
    assert all(r["injected_pkts"] > 0 for r in res)
    assert calls == [(0, "dispatch"), (0, "retry"),
                     (1, "dispatch"), (1, "fetch")]


# ---- validate mode ------------------------------------------------------

def test_validate_clean_pass_is_inert():
    """validate=True never changes the dynamics: every PARITY_KEY is
    bit-identical with the guards on, and the device-fold path still
    does exactly one trace and one host transfer."""
    batch = S.sweep_grid(traces=("university",), gating=(True,),
                         rate_scales=(1.5,))
    plain = S.run_sweep(batch, 800, chunk_ticks=300)
    t0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
    checked = S.run_sweep(batch, 800, chunk_ticks=300, validate=True)
    assert S.TRACE_COUNT - t0 == 1
    assert S.HOST_TRANSFER_COUNT - h0 == 1
    diff, key = S.worst_parity(plain, checked)
    assert diff == 0.0, key


def test_validate_trips_and_localizes():
    """An impossible tolerance trips the conservation guard on the very
    first chunk, naming every failing scenario label."""
    batch = S.sweep_grid(traces=("university",), gating=(True, False),
                         rate_scales=(1.5,))
    with pytest.raises(S.SweepValidationError) as ei:
        S.run_sweep(batch, 800, chunk_ticks=300, validate=True,
                    validate_tol=-1.0)
    err = ei.value
    assert err.first_bad_chunk == 0
    assert set(err.labels) == set(batch.labels)


def test_validate_host_fold_path():
    """The legacy host-fold path supports the finite-value guard too
    (its per-chunk accumulators are checked instead of the fold)."""
    batch = S.sweep_grid(traces=("university",), gating=(True,))
    res = S.run_sweep(batch, 600, chunk_ticks=300, fold="host",
                      validate=True)
    assert res[0]["injected_pkts"] > 0
