"""Runtime contract sanitizers (repro.analysis.sanitizer): the
transfer guard around the blessed fetch points, the log_compiles
recompile watcher, and the TRACE_HOOK ledger that turns the planner
pipeline's one-trace-per-bucket contract into a hard assertion naming
the offending bucket's hull tag."""
import jax.numpy as jnp
import pytest

from repro.analysis.sanitizer import (CompileWatcher, SanitizerSession,
                                      TraceLedger)
from repro.core import simulator as S
from repro.core.topology import FBSite, full_site_tag
from repro.core.traffic import TRAFFIC_SPECS

# own (ticks, chunk) shape: other modules pin exact trace counts around
# their own sweeps, so this module must not pre-warm their caches
TICKS, CHUNK = 440, 220

SITE_A = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                csw_per_cluster=3, n_fc=2, csw_ring_links=4,
                fc_ring_links=8)
SITE_B = FBSite(n_clusters=3, racks_per_cluster=4, servers_per_rack=6,
                csw_per_cluster=2, n_fc=3, csw_ring_links=4,
                fc_ring_links=8)


@pytest.fixture(scope="module")
def mixed_runs():
    h, u = TRAFFIC_SPECS["fb_hadoop"], TRAFFIC_SPECS["university"]
    return [(S.SimParams(spec=h, site=SITE_A), 0),
            (S.SimParams(spec=u, site=SITE_B, rate_scale=1.5), 1),
            (S.SimParams(spec=h, site=SITE_A, gating_enabled=False), 2),
            (S.SimParams(spec=u, site=SITE_B), 3)]


# ---- recompile watcher -------------------------------------------------

def test_compile_watcher_counts_retraces():
    import jax

    def probe(x):
        return x * 2 + 1

    probe_jit = jax.jit(probe)
    with CompileWatcher() as cw:
        probe_jit(jnp.ones(3))
        probe_jit(jnp.ones(3))            # cache hit: no event
        probe_jit(jnp.ones(4))            # new shape: retrace
    assert cw.compiles_of("probe") == 2
    assert cw.events.count("probe") == 2


# ---- transfer guard + ledger around a real sweep -----------------------

def test_sweep_runs_clean_under_sanitizer(sweep_sanitizer, mixed_runs):
    """The full sweep engine under transfer_guard("disallow"): the
    blessed explicit device_get fetches stay legal, and the ledger
    sees exactly the traces the TRACE_COUNT pin counts."""
    n0 = S.TRACE_COUNT
    res = S.run_sweep(S.make_multi_site_batch(mixed_runs), TICKS,
                      chunk_ticks=CHUNK)
    assert len(res) == len(mixed_runs)
    assert sweep_sanitizer.traces.new_traces() == S.TRACE_COUNT - n0
    # every hull the ledger saw is this module's padded hull
    assert set(sweep_sanitizer.traces.tags) <= \
        {full_site_tag(S.make_multi_site_batch(mixed_runs).hull)}


# ---- one-trace-per-bucket under pipeline=True --------------------------

def test_pipeline_one_trace_per_bucket(sweep_sanitizer, mixed_runs):
    """Satellite 6: under pipeline=True every plan bucket compiles
    exactly once, attributed per-hull by the TRACE_HOOK ledger (not
    just a drifted global total)."""
    S._sweep_runner.cache_clear()         # force fresh traces in-window
    res, plan = S.run_sweep_planned(mixed_runs, TICKS,
                                    chunk_ticks=CHUNK, max_compiles=2,
                                    pipeline=True, return_plan=True)
    assert plan["n_buckets"] == 2
    sweep_sanitizer.assert_one_trace_per_bucket(plan)
    assert sorted(sweep_sanitizer.traces.tags) == \
        sorted(b["hull"] for b in plan["buckets"])
    # the recompile watcher agrees: one XLA compile of the sweep step
    # per bucket
    assert sweep_sanitizer.compiles.compiles_of(
        "_sweep_chunk_impl") == plan["n_buckets"]
    assert [r["label"] for r in res] == \
        list(S.make_multi_site_batch(mixed_runs).labels)


def _session_with(sites):
    tl = TraceLedger()
    tl.sites = list(sites)
    return SanitizerSession(compiles=CompileWatcher(), traces=tl)


def test_retraced_bucket_fails_with_hull_tag():
    tag_a = full_site_tag(SITE_A)
    plan = {"buckets": [{"hull": tag_a}]}
    with pytest.raises(AssertionError, match="traced 2x") as ei:
        _session_with([SITE_A, SITE_A]).assert_one_trace_per_bucket(
            plan)
    assert tag_a in str(ei.value)         # names the guilty bucket


def test_untraced_bucket_fails_with_hull_tag():
    tag_a = full_site_tag(SITE_A)
    plan = {"buckets": [{"hull": tag_a}]}
    with pytest.raises(AssertionError, match="never traced") as ei:
        _session_with([]).assert_one_trace_per_bucket(plan)
    assert tag_a in str(ei.value)


def test_stray_hull_fails_with_hull_tag():
    plan = {"buckets": [{"hull": full_site_tag(SITE_A)}]}
    with pytest.raises(AssertionError, match="undeclared") as ei:
        _session_with([SITE_A, SITE_B]).assert_one_trace_per_bucket(
            plan)
    assert full_site_tag(SITE_B) in str(ei.value)


def test_ledger_restores_previous_hook():
    sentinel = object()
    S.TRACE_HOOK = None
    with TraceLedger():
        assert S.TRACE_HOOK is not None
        with TraceLedger() as inner:
            S.TRACE_HOOK("fake-site")     # chains to the outer ledger
            assert inner.sites == ["fake-site"]
    assert S.TRACE_HOOK is None
    del sentinel
