"""Batched multi-scenario sweep engine: parity with the serial path,
the one-compile contract, and the device-resident accumulator fold
(one host transfer per run, <= 1e-6 parity vs the legacy host fold)."""
import pytest

from repro.core import simulator as S
from repro.core.traffic import TRAFFIC_SPECS

TICKS = 1_500
PARITY_KEYS = S.PARITY_KEYS


@pytest.fixture(scope="module")
def grid():
    """2 traces x {gating on/off} x 2 seeds = 8 scenarios."""
    return [(S.SimParams(spec=TRAFFIC_SPECS[t], gating_enabled=g), seed)
            for t in ("fb_hadoop", "university")
            for g in (True, False)
            for seed in (0, 1)]


@pytest.fixture(scope="module")
def sweep_results(grid):
    return S.run_sweep(S.make_batch(grid), TICKS)


def test_sweep_matches_serial_run_sim(grid, sweep_results):
    for (params, seed), batched in zip(grid, sweep_results):
        serial = S.run_sim(params, TICKS, seed)
        for k in PARITY_KEYS:
            a, b = serial[k], batched[k]
            assert abs(a - b) <= 1e-3 * max(abs(a), abs(b), 1e-9), \
                (batched["label"], k, a, b)


def test_sweep_scenarios_are_independent(sweep_results):
    """Scenario knobs must not leak across the batch axis: gated and
    always-on scenarios of the same trace/seed share traffic but not
    energy behaviour."""
    by_label = {r["label"]: r for r in sweep_results}
    lc = by_label["fb_hadoop|lcdc|x1|s0"]
    base = by_label["fb_hadoop|base|x1|s0"]
    assert base["switch_energy_savings_frac"] == 0.0
    assert 0.05 <= lc["switch_energy_savings_frac"] <= 0.75
    # distinct seeds must give distinct traffic
    assert (by_label["fb_hadoop|lcdc|x1|s0"]["injected_pkts"]
            != by_label["fb_hadoop|lcdc|x1|s1"]["injected_pkts"])


def test_sweep_compiles_once():
    """The one-compile contract: same-shaped sweeps with different knob
    values (traces, watermarks, seeds) reuse one traced program, and
    chunking — including a masked remainder tail — does not add traces."""
    batch_a = S.sweep_grid(traces=("fb_hadoop", "fb_web"), seeds=(0,))
    batch_b = S.sweep_grid(traces=("microsoft", "university"), seeds=(3,),
                           hi=0.5, lo=0.1)
    n0 = S.TRACE_COUNT
    S.run_sweep(batch_a, 400, chunk_ticks=200)   # 2 chunks, 1 trace
    n1 = S.TRACE_COUNT
    assert n1 - n0 == 1
    S.run_sweep(batch_b, 600, chunk_ticks=200)   # same shapes: 0 traces
    assert S.TRACE_COUNT == n1
    # remainder: 500 = 2*200 + a masked 100-tick tail, SAME fixed-length
    # chunk program — still zero new traces (ROADMAP item closed)
    S.run_sweep(batch_b, 500, chunk_ticks=200)
    assert S.TRACE_COUNT == n1


def test_chunked_matches_unchunked():
    """Accumulator folding at chunk boundaries must not change metrics —
    with and without a remainder tail chunk."""
    batch = S.sweep_grid(traces=("fb_hadoop",), gating=(True,))
    whole = S.run_sweep(batch, 1_000, chunk_ticks=10_000)[0]
    chunked = S.run_sweep(batch, 1_000, chunk_ticks=250)[0]
    # 1000 = 3*300 + 100: the tail runs the same 300-tick program with
    # the last 200 ticks masked dead (carry passes through unchanged)
    remainder = S.run_sweep(batch, 1_000, chunk_ticks=300)[0]
    for k in PARITY_KEYS:
        a, b, c = whole[k], chunked[k], remainder[k]
        assert abs(a - b) <= 1e-6 * max(abs(a), abs(b), 1.0), (k, a, b)
        assert abs(a - c) <= 1e-6 * max(abs(a), abs(c), 1.0), (k, a, c)


def test_device_fold_matches_host_fold():
    """The device-resident Kahan fold must reproduce the legacy
    per-chunk host-float64 fold to <= 1e-6 relative on a multi-chunk
    run (compensation holds the cross-chunk f32 error at O(eps))."""
    batch = S.sweep_grid(traces=("fb_hadoop",), gating=(True, False))
    dev = S.run_sweep(batch, 1_000, chunk_ticks=250)
    host = S.run_sweep(batch, 1_000, chunk_ticks=250, fold="host")
    worst, worst_key = S.worst_parity(host, dev)
    assert worst <= 1e-6, (worst, worst_key)


def test_device_fold_is_one_host_transfer():
    """The whole point of the device-resident fold: a multi-chunk run
    performs exactly ONE accumulator host transfer (the final fold
    fetch), where the host-fold path pays one per chunk."""
    batch = S.sweep_grid(traces=("fb_web",), gating=(True, False))
    h0 = S.HOST_TRANSFER_COUNT
    S.run_sweep(batch, 800, chunk_ticks=200)         # 4 chunks
    assert S.HOST_TRANSFER_COUNT - h0 == 1
    h0 = S.HOST_TRANSFER_COUNT
    S.run_sweep(batch, 800, chunk_ticks=200, fold="host")
    assert S.HOST_TRANSFER_COUNT - h0 == 4


def test_chunk_boundary_invariance():
    """Same metrics for chunk_ticks in {1k, 10k, n_ticks} on the
    device-fold path: where the chunk boundaries fall (and how many
    device folds happen) must not shift results beyond accumulation
    noise. n_ticks exceeds 10k so the three chunkings genuinely
    differ: 12 folds + no tail, 2 folds + a masked tail, and 1 fold.
    The tolerance is 1e-5, not the fold-parity 1e-6: the cross-chunk
    fold is Kahan-exact, but the IN-scan f32 accumulators round
    differently over a 12k-tick chunk than over a 1k-tick one (that
    growth is exactly why chunking exists; observed ~1e-6)."""
    batch = S.sweep_grid(traces=("university",), gating=(True,))
    n_ticks = 12_000
    res = {c: S.run_sweep(batch, n_ticks, chunk_ticks=c)[0]
           for c in (1_000, 10_000, n_ticks)}
    ref = res[1_000]
    for c, r in res.items():
        for k in PARITY_KEYS:
            a, b = ref[k], r[k]
            assert abs(a - b) <= 1e-5 * max(abs(a), abs(b), 1.0), \
                (c, k, a, b)


def test_seed_key_build_accepts_any_int():
    """The vectorized key build must keep PRNGKey's own seed
    canonicalization: any Python int truncates to its low 32 bits, so
    negative / 64-bit seeds neither crash nor change stream."""
    p = S.SimParams(spec=TRAFFIC_SPECS["fb_hadoop"])
    a = S.run_sweep(S.make_batch([(p, -1), (p, 2**32 + 5)]), 300)
    b = S.run_sweep(S.make_batch([(p, 2**32 - 1), (p, 5)]), 300)
    for ra, rb in zip(a, b):
        assert ra["injected_pkts"] == rb["injected_pkts"]
        assert ra["delivered_pkts"] == rb["delivered_pkts"]


def test_fold_rejects_unknown_mode():
    batch = S.sweep_grid(traces=("fb_hadoop",), gating=(True,))
    with pytest.raises(ValueError, match="fold"):
        S.run_sweep(batch, 100, fold="gpu")


def test_rate_scale_is_a_batch_axis():
    """Utilization sweeps ride the same compile: higher rate_scale must
    inject more and keep more links on."""
    batch = S.sweep_grid(traces=("microsoft",), gating=(True,),
                         rate_scales=(0.3, 1.5))
    lo, hi = S.run_sweep(batch, 1_200)
    assert hi["injected_pkts"] > lo["injected_pkts"]
    assert hi["rsw_link_on_frac"] >= lo["rsw_link_on_frac"] - 0.02
