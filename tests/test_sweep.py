"""Batched multi-scenario sweep engine: parity with the serial path and
the one-compile contract."""
import pytest

from repro.core import simulator as S
from repro.core.traffic import TRAFFIC_SPECS

TICKS = 1_500
PARITY_KEYS = S.PARITY_KEYS


@pytest.fixture(scope="module")
def grid():
    """2 traces x {gating on/off} x 2 seeds = 8 scenarios."""
    return [(S.SimParams(spec=TRAFFIC_SPECS[t], gating_enabled=g), seed)
            for t in ("fb_hadoop", "university")
            for g in (True, False)
            for seed in (0, 1)]


@pytest.fixture(scope="module")
def sweep_results(grid):
    return S.run_sweep(S.make_batch(grid), TICKS)


def test_sweep_matches_serial_run_sim(grid, sweep_results):
    for (params, seed), batched in zip(grid, sweep_results):
        serial = S.run_sim(params, TICKS, seed)
        for k in PARITY_KEYS:
            a, b = serial[k], batched[k]
            assert abs(a - b) <= 1e-3 * max(abs(a), abs(b), 1e-9), \
                (batched["label"], k, a, b)


def test_sweep_scenarios_are_independent(sweep_results):
    """Scenario knobs must not leak across the batch axis: gated and
    always-on scenarios of the same trace/seed share traffic but not
    energy behaviour."""
    by_label = {r["label"]: r for r in sweep_results}
    lc = by_label["fb_hadoop|lcdc|x1|s0"]
    base = by_label["fb_hadoop|base|x1|s0"]
    assert base["switch_energy_savings_frac"] == 0.0
    assert 0.05 <= lc["switch_energy_savings_frac"] <= 0.75
    # distinct seeds must give distinct traffic
    assert (by_label["fb_hadoop|lcdc|x1|s0"]["injected_pkts"]
            != by_label["fb_hadoop|lcdc|x1|s1"]["injected_pkts"])


def test_sweep_compiles_once():
    """The one-compile contract: same-shaped sweeps with different knob
    values (traces, watermarks, seeds) reuse one traced program, and
    chunking — including a masked remainder tail — does not add traces."""
    batch_a = S.sweep_grid(traces=("fb_hadoop", "fb_web"), seeds=(0,))
    batch_b = S.sweep_grid(traces=("microsoft", "university"), seeds=(3,),
                           hi=0.5, lo=0.1)
    n0 = S.TRACE_COUNT
    S.run_sweep(batch_a, 400, chunk_ticks=200)   # 2 chunks, 1 trace
    n1 = S.TRACE_COUNT
    assert n1 - n0 == 1
    S.run_sweep(batch_b, 600, chunk_ticks=200)   # same shapes: 0 traces
    assert S.TRACE_COUNT == n1
    # remainder: 500 = 2*200 + a masked 100-tick tail, SAME fixed-length
    # chunk program — still zero new traces (ROADMAP item closed)
    S.run_sweep(batch_b, 500, chunk_ticks=200)
    assert S.TRACE_COUNT == n1


def test_chunked_matches_unchunked():
    """Accumulator folding at chunk boundaries must not change metrics —
    with and without a remainder tail chunk."""
    batch = S.sweep_grid(traces=("fb_hadoop",), gating=(True,))
    whole = S.run_sweep(batch, 1_000, chunk_ticks=10_000)[0]
    chunked = S.run_sweep(batch, 1_000, chunk_ticks=250)[0]
    # 1000 = 3*300 + 100: the tail runs the same 300-tick program with
    # the last 200 ticks masked dead (carry passes through unchanged)
    remainder = S.run_sweep(batch, 1_000, chunk_ticks=300)[0]
    for k in PARITY_KEYS:
        a, b, c = whole[k], chunked[k], remainder[k]
        assert abs(a - b) <= 1e-6 * max(abs(a), abs(b), 1.0), (k, a, b)
        assert abs(a - c) <= 1e-6 * max(abs(a), abs(c), 1.0), (k, a, c)


def test_rate_scale_is_a_batch_axis():
    """Utilization sweeps ride the same compile: higher rate_scale must
    inject more and keep more links on."""
    batch = S.sweep_grid(traces=("microsoft",), gating=(True,),
                         rate_scales=(0.3, 1.5))
    lo, hi = S.run_sweep(batch, 1_200)
    assert hi["injected_pkts"] > lo["injected_pkts"]
    assert hi["rsw_link_on_frac"] >= lo["rsw_link_on_frac"] - 0.02
