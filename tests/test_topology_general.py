"""Topology-general sweeps: FBSite invariant enforcement, conservation
on deliberately non-default (yet wiring-consistent) sites, and the
multi-site padded batch (one compile + single-site parity)."""
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

# non-default square sites: different cluster counts, rack counts, plane
# counts and FC counts than the Fig 2 default (4x32, c4, f4)
SITE_A = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                csw_per_cluster=3, n_fc=2, csw_ring_links=4,
                fc_ring_links=8)
SITE_B = FBSite(n_clusters=3, racks_per_cluster=4, servers_per_rack=6,
                csw_per_cluster=2, n_fc=3, csw_ring_links=4,
                fc_ring_links=8)


# ---- FBSite wiring invariants ------------------------------------------

def test_uplinks_derived_from_wiring():
    assert FBSite().rsw_uplinks == 4 and FBSite().csw_uplinks == 4
    s = FBSite(csw_per_cluster=3, n_fc=2)
    assert s.rsw_uplinks == 3            # one uplink per cluster CSW
    assert s.csw_uplinks == 2            # one uplink per fabric core
    # explicitly passing CONSISTENT values is allowed
    assert FBSite(rsw_uplinks=4, csw_uplinks=4) == FBSite()


def test_inconsistent_uplinks_rejected():
    with pytest.raises(ValueError, match="rsw_uplinks"):
        FBSite(rsw_uplinks=8)            # csw_per_cluster stays 4
    with pytest.raises(ValueError, match="csw_uplinks"):
        FBSite(csw_uplinks=2)            # n_fc stays 4
    with pytest.raises(ValueError, match="must be >= 1"):
        FBSite(n_clusters=0)


def test_make_batch_rejects_mixed_sites():
    spec = TRAFFIC_SPECS["fb_hadoop"]
    with pytest.raises(AssertionError, match="make_multi_site_batch"):
        S.make_batch([(S.SimParams(spec=spec, site=SITE_A), 0),
                      (S.SimParams(spec=spec, site=SITE_B), 0)])


# ---- conservation regression (injected == delivered + in-flight + drops)

def _conservation_error(site, ticks, rate_scale=1.0):
    runs = [(S.SimParams(spec=TRAFFIC_SPECS["fb_hadoop"], site=site,
                         rate_scale=rate_scale), 0)]
    res, st = S.run_sweep(S.make_batch(runs), ticks, return_state=True)
    r = res[0]
    in_flight = sum(float(np.sum(np.asarray(q)[0]))
                    for q in (st.rsw_q, st.csw_up_q, st.csw_down_q,
                              st.fc_down_q))
    inj = r["injected_pkts"]
    drops = r["drop_frac"] * inj
    err = inj - (r["delivered_pkts"] + drops + in_flight)
    assert inj > 0, "no traffic injected — test is vacuous"
    return abs(err) / max(inj, 1e-9)


def test_conservation_non_default_site():
    """A non-square-default site must not leak or invent packets: the
    step-4/6/7 down-plane math runs on the csw_per_cluster plane axis
    and the csw_uplinks FC axis, not the conflated defaults."""
    assert _conservation_error(SITE_A, 3_000, rate_scale=1.5) < 1e-3


def test_conservation_default_site():
    assert _conservation_error(FBSite(), 2_000) < 1e-3


# ---- multi-site batch: one compile + single-site parity ----------------

@pytest.fixture(scope="module")
def mixed_runs():
    """2 distinct sites x {LC/DC, always-on}, mixed specs and seeds."""
    h, u = TRAFFIC_SPECS["fb_hadoop"], TRAFFIC_SPECS["university"]
    return [(S.SimParams(spec=h, site=SITE_A), 0),
            (S.SimParams(spec=h, site=SITE_A, gating_enabled=False), 0),
            (S.SimParams(spec=u, site=SITE_B, rate_scale=1.5), 1),
            (S.SimParams(spec=u, site=SITE_B, gating_enabled=False), 1)]


@pytest.fixture(scope="module")
def mixed_results(mixed_runs):
    """One multi-site sweep with a remainder tail (700 = 2*300 + 100);
    captures the trace count delta around the run."""
    n0 = S.TRACE_COUNT
    res = S.run_sweep(S.make_multi_site_batch(mixed_runs), 700,
                      chunk_ticks=300)
    return res, S.TRACE_COUNT - n0


def test_multi_site_batch_compiles_once(mixed_results):
    """A mixed batch of heterogeneous sites is ONE vmapped compile,
    including the masked remainder tail chunk."""
    _, traces = mixed_results
    assert traces == 1


def test_multi_site_labels_tagged(mixed_results):
    res, _ = mixed_results
    assert res[0]["label"].endswith("|2x8c3f2")
    assert res[2]["label"].endswith("|3x4c2f3")
    assert len({r["label"] for r in res}) == len(res)


def test_multi_site_parity_with_single_site(mixed_runs, mixed_results):
    """Each scenario padded into the hull must reproduce its single-site
    run_sweep metrics: padding rows are inert and the per-rack PRNG is
    keyed on logical rack ids, not hull positions."""
    res, _ = mixed_results
    for run, mixed in zip(mixed_runs, res):
        single = S.run_sweep(S.make_batch([run]), 700, chunk_ticks=300)[0]
        for k in S.PARITY_KEYS:
            a, b = single[k], mixed[k]
            assert abs(a - b) <= 1e-3 * max(abs(a), abs(b), 1e-9), \
                (mixed["label"], k, a, b)


def test_multi_site_baseline_vs_gated(mixed_results):
    """Per-site sanity: always-on scenarios show no savings; gated ones
    save energy on whatever topology they run."""
    res, _ = mixed_results
    assert res[1]["switch_energy_savings_frac"] == 0.0
    assert res[3]["switch_energy_savings_frac"] == 0.0
    assert 0.0 <= res[0]["switch_energy_savings_frac"] <= 0.75
    # stage 1 of a 3-plane site floors at 1/3 on; of a 2-plane at 1/2
    assert res[0]["rsw_link_on_frac"] >= 1.0 / 3 - 1e-9
    assert res[2]["rsw_link_on_frac"] >= 0.5 - 1e-9
