"""Hull-bucketing sweep planner: partition/cost properties (pure
python, no sim), and the planned execution path — K=1 degenerate parity
with make_multi_site_batch, caller-order restoration under shuffled
inputs, and the one-compile-per-bucket contract."""
import pytest
from hypothesis import given, strategies as st

from repro.core import planner
from repro.core import simulator as S
from repro.core.topology import FBSite, pad_hull
from repro.core.traffic import TRAFFIC_SPECS

# the same small heterogeneous sites as tests/test_topology_general.py,
# but on a DIFFERENT (ticks, chunk) shape: that module pins an exact
# trace count around its own sweep, so these tests must not pre-warm
# its executable cache
SITE_A = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                csw_per_cluster=3, n_fc=2, csw_ring_links=4,
                fc_ring_links=8)
SITE_B = FBSite(n_clusters=3, racks_per_cluster=4, servers_per_rack=6,
                csw_per_cluster=2, n_fc=3, csw_ring_links=4,
                fc_ring_links=8)
TICKS, CHUNK = 600, 250

# bimodal mix: 3 small + 3 large fabrics (cheap pure-planner checks;
# the executed acceptance version lives in benchmarks/bench_sweep.py)
_SM = dict(n_clusters=2, servers_per_rack=8, csw_per_cluster=2, n_fc=2,
           csw_ring_links=4, fc_ring_links=8)
BIMODAL = (FBSite(racks_per_cluster=4, **_SM),
           FBSite(racks_per_cluster=5, **_SM),
           FBSite(racks_per_cluster=6, **_SM),
           FBSite(), FBSite(racks_per_cluster=28),
           FBSite(racks_per_cluster=24))


# ---- cost model --------------------------------------------------------

def test_flow_slots_in_sync():
    """The planner's jax-free copy of the flow-slot width must track the
    simulator's actual constant (the dominant cost-model term)."""
    assert planner.FLOW_SLOTS == S.F_SLOTS


def test_site_cost_monotone_per_axis():
    base = FBSite()
    for field, bigger in (("n_clusters", 8), ("racks_per_cluster", 64),
                          ("csw_per_cluster", 8), ("n_fc", 8)):
        grown = FBSite(**{field: bigger})
        assert planner.site_cost(grown) > planner.site_cost(base), field


def test_plan_rejects_bad_inputs():
    with pytest.raises(ValueError, match="empty"):
        planner.plan_sites([])
    with pytest.raises(ValueError, match="max_compiles"):
        planner.plan_sites([FBSite()], max_compiles=0)


# ---- bucketing properties (pure python) --------------------------------

_POOL = (FBSite(n_clusters=1, racks_per_cluster=1, servers_per_rack=1,
                csw_per_cluster=1, n_fc=1, csw_ring_links=1,
                fc_ring_links=1),
         BIMODAL[0], SITE_A, SITE_B, FBSite())


@given(st.lists(st.integers(0, 4), min_size=1, max_size=12),
       st.integers(1, 5))
def test_bucketing_is_an_exact_partition(idxs, k):
    """Bucketing never drops or duplicates a scenario, respects the
    compile budget, fits every member inside its bucket hull, and its
    padded cost is monotone: ideal <= plan(K) <= plan(K-1) <= ... <=
    single hull."""
    sites = [_POOL[i] for i in idxs]
    plan = planner.plan_sites(sites, max_compiles=k)
    seen = sorted(i for b in plan.buckets for i in b.indices)
    assert seen == list(range(len(sites)))           # no drop, no dup
    assert 1 <= len(plan.buckets) <= min(k, len(set(idxs)))
    for b in plan.buckets:
        assert b.hull == pad_hull([sites[i] for i in b.indices])
        for i in b.indices:
            s, h = sites[i], b.hull
            assert (s.n_clusters <= h.n_clusters
                    and s.racks_per_cluster <= h.racks_per_cluster
                    and s.servers_per_rack <= h.servers_per_rack
                    and s.csw_per_cluster <= h.csw_per_cluster
                    and s.n_fc <= h.n_fc)
    assert plan.ideal_cost <= plan.padded_cost + 1e-9
    assert plan.padded_cost <= plan.single_hull_cost + 1e-9
    if k > 1:
        tighter_budget = planner.plan_sites(sites, max_compiles=k - 1)
        assert plan.padded_cost <= tighter_budget.padded_cost + 1e-9


def test_exact_site_groups_have_zero_waste():
    """Budget >= distinct sites: every bucket hull IS its site — zero
    padding waste, and identical sites share one bucket."""
    sites = [SITE_A, SITE_B, SITE_A, SITE_B, SITE_A]
    plan = planner.plan_sites(sites, max_compiles=4)
    assert len(plan.buckets) == 2
    for b in plan.buckets:
        assert b.waste_frac == 0.0
    assert plan.waste_frac == 0.0


def test_bimodal_waste_monotone_and_savings():
    """The acceptance shape, statically: on the 3-small + 3-large mix a
    2-bucket plan cuts >= 30% of the single-hull padded compute, and
    padded waste with K=2 is <= K=1."""
    p1 = planner.plan_sites(BIMODAL, max_compiles=1)
    p2 = planner.plan_sites(BIMODAL, max_compiles=2)
    assert p1.savings_vs_single_hull_frac == 0.0     # K=1 IS the hull
    assert p2.waste_frac <= p1.waste_frac + 1e-9
    assert p2.padded_cost <= p1.padded_cost + 1e-9
    assert p2.savings_vs_single_hull_frac >= 0.30
    # the greedy merge must split small from large, not mix them
    assert sorted(tuple(b.indices) for b in p2.buckets) == \
        [(0, 1, 2), (3, 4, 5)]


def test_dispatch_order_largest_cost_first():
    """The async pipeline dispatches the most expensive bucket first so
    cheaper buckets' compiles overlap its execution; the order is a
    permutation of the buckets and deterministic."""
    plan = planner.plan_sites(BIMODAL, max_compiles=2)
    order = plan.dispatch_order
    assert sorted(order) == list(range(len(plan.buckets)))
    costs = [plan.buckets[k].padded_cost for k in order]
    assert costs == sorted(costs, reverse=True)
    assert plan.report()["dispatch_order"] == list(order)


def test_fingerprint_tracks_plan_not_call_order():
    sites = [SITE_A, SITE_B, SITE_A]
    a = planner.plan_sites(sites, max_compiles=2)
    b = planner.plan_sites(list(sites), max_compiles=2)
    assert a.fingerprint == b.fingerprint            # deterministic
    c = planner.plan_sites(sites, max_compiles=1)    # different buckets
    assert c.fingerprint != a.fingerprint


# ---- planned execution: parity + caller order + compile contract -------

@pytest.fixture(scope="module")
def mixed_runs():
    h, u = TRAFFIC_SPECS["fb_hadoop"], TRAFFIC_SPECS["university"]
    return [(S.SimParams(spec=h, site=SITE_A), 0),
            (S.SimParams(spec=h, site=SITE_A, gating_enabled=False), 0),
            (S.SimParams(spec=u, site=SITE_B, rate_scale=1.5), 1),
            (S.SimParams(spec=u, site=SITE_B, gating_enabled=False), 1)]


def test_k1_degenerate_matches_make_multi_site_batch(mixed_runs):
    """max_compiles=1 is the old single-hull path, bit for bit: same
    labels, same metrics (the planner only adds the plan_* keys)."""
    single = S.run_sweep(S.make_multi_site_batch(mixed_runs), TICKS,
                         chunk_ticks=CHUNK)
    planned = S.run_sweep_planned(mixed_runs, TICKS, chunk_ticks=CHUNK,
                                  max_compiles=1)
    for a, b in zip(single, planned):
        assert a["label"] == b["label"]
        assert b["plan_bucket"] == 0
        for k in S.PARITY_KEYS:
            assert abs(a[k] - b[k]) <= 1e-3 * max(abs(a[k]), abs(b[k]),
                                                  1e-9), (k, a[k], b[k])


def test_planned_restores_caller_order_and_compiles_per_bucket(mixed_runs):
    """Shuffled heterogeneous input comes back in caller order (labels
    line up with make_multi_site_batch's for the same run list), each
    bucket compiles exactly once, and a re-run under a different
    shuffle reuses both executables and yields identical metrics."""
    shuffled = [mixed_runs[i] for i in (2, 0, 3, 1)]   # interleave sites
    expect_labels = S.make_multi_site_batch(shuffled).labels

    n0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
    res, plan = S.run_sweep_planned(shuffled, TICKS, chunk_ticks=CHUNK,
                                    max_compiles=2, return_plan=True)
    assert S.TRACE_COUNT - n0 == plan["n_buckets"] == 2
    # async bucket pipeline: one fold fetch per bucket, nothing per chunk
    assert S.HOST_TRANSFER_COUNT - h0 == plan["n_buckets"]
    assert [r["label"] for r in res] == list(expect_labels)
    # bucket membership: same-site scenarios share a bucket+hull tag
    assert res[0]["plan_bucket"] == res[2]["plan_bucket"]
    assert res[1]["plan_bucket"] == res[3]["plan_bucket"]
    assert res[0]["plan_bucket"] != res[1]["plan_bucket"]
    # the full tag, joinable against the plan report's bucket "hull"
    assert res[1]["plan_hull"] == "2x8c3f2s8r4-8"    # SITE_A's own tag
    assert res[1]["plan_hull"] in {b["hull"] for b in plan["buckets"]}

    # different shuffle, same scenarios: cached executables (no new
    # traces) and identical per-label metrics
    reshuffled = [mixed_runs[i] for i in (1, 3, 0, 2)]
    n1 = S.TRACE_COUNT
    res2 = S.run_sweep_planned(reshuffled, TICKS, chunk_ticks=CHUNK,
                               max_compiles=2)
    assert S.TRACE_COUNT == n1
    by_label = {r["label"]: r for r in res}
    for r in res2:
        ref = by_label[r["label"]]
        for k in S.PARITY_KEYS:
            assert r[k] == ref[k], (r["label"], k)


def test_pipelined_matches_serial_bucket_execution(mixed_runs):
    """pipeline=False (strictly serial dispatch+fetch per bucket) is
    bit-identical to the async pipeline: same compiled programs, same
    inputs, only the dispatch schedule differs."""
    piped = S.run_sweep_planned(mixed_runs, TICKS, chunk_ticks=CHUNK,
                                max_compiles=2)
    serial = S.run_sweep_planned(mixed_runs, TICKS, chunk_ticks=CHUNK,
                                 max_compiles=2, pipeline=False)
    for a, b in zip(piped, serial):
        assert a["label"] == b["label"]
        assert a["plan_bucket"] == b["plan_bucket"]
        assert a["plan_hull"] == b["plan_hull"]
        for k in S.PARITY_KEYS:
            assert a[k] == b[k], (a["label"], k)


# ---- cost_model="hlo" (PR 8: blessed-artifact calibration) ---------------

def _mixed_sites():
    return ([FBSite(2, 2, 4, 2, 2)] * 3 + [FBSite(4, 8, 16, 4, 4)] * 2
            + [FBSite(2, 4, 8, 2, 2)])


def test_cost_model_default_is_bitwise_identical():
    """plan_sites() and plan_sites(cost_model="model") must agree with
    the pre-cost_model planner field for field — the default bucketing
    is pinned bit-wise."""
    sites = _mixed_sites()
    for k in (1, 2, 3):
        a = planner.plan_sites(sites, max_compiles=k)
        b = planner.plan_sites(sites, max_compiles=k,
                               cost_model="model")
        assert a == b
        assert a.fingerprint == b.fingerprint
        assert a.report() == b.report()


def test_cost_model_rejects_unknown():
    with pytest.raises(ValueError, match="cost_model"):
        planner.plan_sites(_mixed_sites(), cost_model="bogus")


def test_hlo_cost_fn_exact_hit_and_scaled_fallback():
    """Synthetic table: measured hulls cost exactly their table entry;
    unmeasured hulls get site_cost rescaled by the geometric-mean
    measured/model ratio (2x and 8x -> k = 4)."""
    from repro.core.topology import full_site_tag
    small, large = FBSite(2, 2, 4, 2, 2), FBSite(4, 8, 16, 4, 4)
    table = {
        full_site_tag(small): {
            "flops_per_tick_scen": 2.0 * planner.site_cost(small),
            "site": small},
        full_site_tag(large): {
            "flops_per_tick_scen": 8.0 * planner.site_cost(large),
            "site": large},
    }
    cost = planner.hlo_cost_fn(table)
    assert cost(small) == 2.0 * planner.site_cost(small)
    assert cost(large) == 8.0 * planner.site_cost(large)
    other = FBSite(3, 3, 6, 3, 3)
    assert cost(other) == pytest.approx(4.0 * planner.site_cost(other))
    # empty table degenerates to the hand model unchanged
    bare = planner.hlo_cost_fn({})
    assert bare(other) == planner.site_cost(other)


def test_plan_sites_hlo_mode_uses_the_table():
    """A table that inverts the small/large cost ordering must flip
    which hull the planner merges toward — proof the cost model is
    actually consulted, not just loaded."""
    from repro.core.topology import full_site_tag
    small, large = FBSite(2, 2, 4, 2, 2), FBSite(4, 8, 16, 4, 4)
    sites = [small] * 2 + [large] * 2
    # inverted world: the small hull is 100x the large one
    table = {
        full_site_tag(small): {
            "flops_per_tick_scen": 100.0 * planner.site_cost(large),
            "site": small},
        full_site_tag(large): {
            "flops_per_tick_scen": planner.site_cost(large),
            "site": large},
    }
    plan = planner.plan_sites(sites, max_compiles=2, cost_model="hlo",
                              cost_table=table)
    by_first = {b.indices[0]: b for b in plan.buckets}
    # bucket costs reflect the table, not the hand model
    assert by_first[0].padded_cost == pytest.approx(
        2 * 100.0 * planner.site_cost(large))
    assert by_first[2].padded_cost == pytest.approx(
        2 * planner.site_cost(large))


def test_plan_sites_hlo_mode_loads_committed_contracts():
    """Without an explicit table the HLO mode reads the committed
    artifact contracts; bucketing structure matches the hand model on
    the blessed hulls (the calibration contract keeps the two
    shape-proportional)."""
    sites = _mixed_sites()
    a = planner.plan_sites(sites, max_compiles=2)
    b = planner.plan_sites(sites, max_compiles=2, cost_model="hlo")
    assert [x.indices for x in a.buckets] == \
        [x.indices for x in b.buckets]
    assert a.fingerprint == b.fingerprint   # fingerprint is cost-free
