"""The contract linter's own test corpus (tier 1, no jax needed).

One known-bad fixture per rule pinning the exact rule ID **and line**,
a suppressed case, a registry-drift case, the suppression baseline, and
a self-run asserting the shipped tree is clean. Fixtures build a mini
repo under tmp_path with their own registry so they cannot interfere
with the real compile_sites.toml.
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import toml_lite
from repro.analysis.engine import run_lint
from repro.analysis.findings import RULES, scan_suppressions
from repro.analysis.reachability import dead_code_report
from repro.analysis.registry import Config, load_config

REPO = Path(__file__).resolve().parents[1]

MINI_CFG = """
[analysis]
lint_scope = ["src/demo"]
max_suppressions = {max_sup}
hot_modules = ["src/demo/hot.py"]
bitexact_modules = ["src/demo/exact.py"]
require_scenario_contract = false
{extra}
"""


def mini(tmp_path, files, *, max_sup=0, extra=""):
    """Build a throwaway lint root: files maps relpath -> source."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    cfg = Config(raw=toml_lite.loads(
        MINI_CFG.format(max_sup=max_sup, extra=textwrap.dedent(extra))),
        root=tmp_path)
    return run_lint(tmp_path, cfg)


def hits(rep, rule, suppressed=False):
    return [(f.path, f.line) for f in rep.findings
            if f.rule == rule and f.suppressed == suppressed]


# ---- RL001 traced-control-flow -----------------------------------------

def test_rl001_if_on_traced_value(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                return x
            return -x
        """})
    assert hits(rep, "RL001") == [("src/demo/mod.py", 5)]


def test_rl001_interprocedural_and_statics(tmp_path):
    """Taint flows through a project call; static_argnames params and
    is-None / .ndim checks stay untainted."""
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def helper(v, mode):
            if mode == "fast":          # untainted: mode is static
                v = v * 2
            assert v.ndim == 2          # untainted: shape metadata
            return float(v)             # line 7: RL001 coercion

        def g(x, y=None, mode="slow"):
            if y is None:               # untainted: is-None is static
                y = x
            return helper(x + y, mode)

        run = jax.jit(g, static_argnames=("mode",))
        """})
    assert hits(rep, "RL001") == [("src/demo/mod.py", 7)]


def test_rl001_factory_closure_is_rooted(tmp_path):
    """A step built by a closure factory and handed to scan via a local
    alias is still traced-reachable (the simulator's own shape)."""
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def make_step(n):
            def step(carry, x):
                assert n > 0            # untainted closure const
                while x > 1:            # line 6: RL001
                    x = x - 1
                return carry + x, None
            return step

        def drive(xs):
            step = make_step(4)
            out, _ = jax.lax.scan(step, 0.0, xs)
            return out
        """})
    assert hits(rep, "RL001") == [("src/demo/mod.py", 6)]


# ---- RL002 compile-site registry ---------------------------------------

def test_rl002_unregistered_site_and_drift(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def f(x):
            return jax.jit(lambda v: v + 1)(x)
        """}, extra="""
        [[compile_site]]
        file = "src/demo/mod.py"
        qualname = "gone_function"
        kind = "scan"
        multiplicity = "one"
        """)
    got = hits(rep, "RL002")
    assert ("src/demo/mod.py", 4) in got          # unregistered jit
    assert any("registry drift" in f.message for f in rep.findings
               if f.rule == "RL002")              # declared-but-gone


def test_rl002_registered_site_is_clean(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        @jax.jit
        def f(x):
            return x + 1
        """}, extra="""
        [[compile_site]]
        file = "src/demo/mod.py"
        qualname = "f"
        kind = "jit"
        multiplicity = "one per input shape"
        """)
    assert hits(rep, "RL002") == []


def test_rl002_trace_count_pin_drift(tmp_path):
    """A TRACE_COUNT probe outside [trace_count].counted_fns is drift."""
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        TRACE_COUNT = 0

        def rogue(x):
            global TRACE_COUNT
            TRACE_COUNT += 1
            return x
        """}, extra="""
        [trace_count]
        file = "src/demo/mod.py"
        counted_fns = ["blessed_fn"]
        """)
    msgs = [f.message for f in rep.findings if f.rule == "RL002"]
    assert any("rogue" in m for m in msgs)
    assert any("blessed_fn" in m for m in msgs)


# ---- RL003 host-transfer smell -----------------------------------------

def test_rl003_device_get_outside_blessed(tmp_path):
    rep = mini(tmp_path, {"src/demo/hot.py": """\
        import jax

        def blessed_fetch(x):
            return jax.device_get(x)

        def leaky(x):
            y = jax.device_get(x)
            x.block_until_ready()
            return y
        """}, extra="""
        [[blessed_transfer]]
        file = "src/demo/hot.py"
        qualname = "blessed_fetch"
        reason = "the one declared fetch"
        """)
    assert hits(rep, "RL003") == [("src/demo/hot.py", 7),
                                  ("src/demo/hot.py", 8)]


def test_rl003_np_asarray_on_traced_value(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x).sum()
        """})
    assert hits(rep, "RL003") == [("src/demo/mod.py", 6)]


# ---- RL004 scenario-leaf sync ------------------------------------------

RL004_CODE = """\
    SIM_SCHEMA_VERSION = 3
    FAULT_KNOBS = ("mtbf",)

    class Scenario:
        rate: object
        mtbf: object

    class Params:
        rate: float = 1.0
        mtbf: float = 0.0

        def __post_init__(self):
            assert self.rate >= 0

    def use(s):
        return s.rate + s.mtbf
"""

RL004_CONTRACT = """
    [scenario_contract]
    file = "src/demo/mod.py"
    scenario_class = "Scenario"
    params_class = "Params"
    schema_version = {ver}
    scenario_fields = [{fields}]
    validated_params = ["rate"]
    fingerprint_params = ["mtbf"]

    [[validation_exempt]]
    field = "mtbf"
    reason = "zero disables"
"""


def test_rl004_clean_contract(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": RL004_CODE},
               extra=RL004_CONTRACT.format(ver=3,
                                           fields='"rate", "mtbf"'))
    assert hits(rep, "RL004") == []


def test_rl004_unregistered_leaf_and_version_drift(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": RL004_CODE},
               extra=RL004_CONTRACT.format(ver=4, fields='"rate"'))
    got = hits(rep, "RL004")
    assert ("src/demo/mod.py", 6) in got     # mtbf leaf unregistered
    assert ("src/demo/mod.py", 1) in got     # schema version mismatch


def test_rl004_unvalidated_param(tmp_path):
    code = RL004_CODE.replace('        assert self.rate >= 0\n',
                              '        pass\n')
    rep = mini(tmp_path, {"src/demo/mod.py": code},
               extra=RL004_CONTRACT.format(ver=3,
                                           fields='"rate", "mtbf"'))
    assert ("src/demo/mod.py", 9) in hits(rep, "RL004")  # rate unchecked


# ---- RL005 PRNG discipline ---------------------------------------------

def test_rl005_key_reuse(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def sample(key):
            a = jax.random.uniform(key, (3,))
            b = jax.random.normal(key, (3,))
            return a + b
        """})
    assert hits(rep, "RL005") == [("src/demo/mod.py", 5)]


def test_rl005_fold_in_between_is_clean(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def sample(key):
            a = jax.random.uniform(key, (3,))
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, (3,))
            k1, k2 = jax.random.split(key)
            c = jax.random.uniform(k1) + jax.random.uniform(k2)
            return a + b + c
        """})
    assert hits(rep, "RL005") == []


def test_rl005_reuse_across_loop_iterations(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import jax

        def sample(key, n):
            out = 0.0
            for i in range(n):
                out += jax.random.uniform(key)
            return out
        """})
    assert hits(rep, "RL005") == [("src/demo/mod.py", 6)]


# ---- RL006 dtype discipline --------------------------------------------

def test_rl006_float64_in_bitexact_module(tmp_path):
    rep = mini(tmp_path, {"src/demo/exact.py": """\
        import numpy as np
        import jax.numpy as jnp

        def f(x):
            y = jnp.asarray(x, dtype=np.float64)
            z = x.astype("float64")
            w = jnp.zeros(3, dtype=float)
            return y + z + w
        """})
    assert hits(rep, "RL006") == [("src/demo/exact.py", 5),
                                  ("src/demo/exact.py", 6),
                                  ("src/demo/exact.py", 7)]


def test_rl006_not_applied_outside_bitexact(tmp_path):
    rep = mini(tmp_path, {"src/demo/mod.py": """\
        import numpy as np
        ACC = np.zeros(4, dtype=np.float64)
        """})
    assert hits(rep, "RL006") == []


# ---- suppressions -------------------------------------------------------

def test_suppression_with_reason_suppresses(tmp_path):
    rep = mini(tmp_path, {"src/demo/exact.py": """\
        import numpy as np

        def f(x):
            # repro-lint: disable=RL006(host-side fold wants f64)
            return np.asarray(x, dtype=np.float64)
        """}, max_sup=1)
    assert hits(rep, "RL006") == []
    assert hits(rep, "RL006", suppressed=True) == \
        [("src/demo/exact.py", 5)]
    assert rep.unsuppressed == []
    assert rep.suppression_count == 1


def test_suppression_without_reason_is_rl000(tmp_path):
    rep = mini(tmp_path, {"src/demo/exact.py": """\
        import numpy as np

        def f(x):
            return np.asarray(x, dtype=np.float64)  # repro-lint: disable=RL006
        """}, max_sup=1)
    assert hits(rep, "RL000") == [("src/demo/exact.py", 4)]
    assert hits(rep, "RL006") == [("src/demo/exact.py", 4)]  # NOT hidden


def test_suppression_baseline_only_goes_down(tmp_path):
    rep = mini(tmp_path, {"src/demo/exact.py": """\
        import numpy as np
        # repro-lint: disable=RL006(one)
        A = np.zeros(1, dtype=np.float64)
        # repro-lint: disable=RL006(two)
        B = np.zeros(1, dtype=np.float64)
        """}, max_sup=1)
    assert any(f.rule == "RL000" and "baseline" in f.message
               for f in rep.findings)


def test_suppression_scanner_own_line_targets_next():
    sup = scan_suppressions("x.py", "# repro-lint: disable=RL001(why)\n"
                                    "code_line()\n")
    assert sup.reason_for("RL001", 2) == "why"
    assert sup.reason_for("RL001", 1) is None
    assert sup.count == 1


# ---- the shipped tree ---------------------------------------------------

def test_shipped_tree_is_clean():
    """`python -m repro.analysis --check` contract: zero unsuppressed
    findings on src/repro/{core,kernels} with the committed registry."""
    cfg = load_config(REPO)
    rep = run_lint(REPO, cfg)
    assert rep.unsuppressed == [], "\n".join(
        f.format() for f in rep.unsuppressed)
    assert rep.suppression_count <= cfg.max_suppressions


def test_shipped_registry_round_trips():
    cfg = load_config(REPO)
    assert cfg.lint_scope == ["src/repro/core", "src/repro/kernels",
                              "benchmarks", "examples"]
    assert cfg.max_suppressions >= 0
    assert {e["kind"] for e in cfg.raw["compile_site"]} == \
        {"jit", "scan", "pallas_call"}
    assert cfg.blessed("src/repro/core/simulator.py") == \
        {"_dispatch_chunks", "_finish_sweep", "_snapshot_sweep"}
    sc = cfg.raw["scenario_contract"]
    assert sc["schema_version"] == 8
    assert list(sc["fingerprint_params"]) == [
        "wake_fail_prob", "wake_jitter_frac", "link_mtbf_ticks",
        "repair_ticks", "fault_fallback", "plane_fail_prob"]
    assert list(sc["flow_fingerprint_params"]) == [
        "flow_mode", "flow_arrival_rate", "flow_size_dist",
        "incast_degree", "flow_table_cap"]


def test_rules_table_is_complete():
    assert sorted(RULES) == [f"RL00{i}" for i in range(10)]
    for rule, (name, invariant) in RULES.items():
        assert name and invariant, rule


def test_dead_code_report_reachability():
    cfg = load_config(REPO)
    rep = dead_code_report(REPO, cfg.lint_exempt)
    reach = set(rep["reachable"])
    # the engine and its oracles must be reachable from the roots
    for mod in ("repro.core.simulator", "repro.core.planner",
                "repro.core.gating", "repro.kernels.ref",
                "repro.models.attention", "repro.models.rwkv6"):
        assert mod in reach, mod
    # everything unreachable is an inventoried exempt seed module
    for u in rep["unreachable"]:
        assert u["exempt"], f"non-exempt dead module: {u['module']}"


def test_cli_check_and_json(tmp_path):
    """End-to-end CLI: --check exits 0 on the shipped tree and the
    --json report is well-formed. --no-artifacts keeps this leg
    jax-free and fast; the artifact audit has its own CLI test in
    tests/test_artifact.py."""
    from repro.analysis.cli import main
    out = tmp_path / "report.json"
    rc = main(["--check", "--no-artifacts", "--json", str(out),
               "--root", str(REPO), "-q"])
    assert rc == 0
    rep = json.loads(out.read_text())
    assert rep["n_unsuppressed"] == 0
    assert rep["suppressions"]["count"] <= \
        rep["suppressions"]["baseline"]
    assert set(rep["rules"]) == set(RULES)
    assert "artifact" not in rep           # audit skipped, not empty


def test_cli_check_fails_on_bad_tree(tmp_path):
    from repro.analysis.cli import main
    (tmp_path / "src/demo").mkdir(parents=True)
    (tmp_path / "src/repro/analysis").mkdir(parents=True)
    (tmp_path / "src/demo/mod.py").write_text(
        "import jax\n\n@jax.jit\ndef f(x):\n    if x > 0:\n"
        "        return x\n    return -x\n")
    (tmp_path / "src/repro/analysis/compile_sites.toml").write_text(
        '[analysis]\nlint_scope = ["src/demo"]\n'
        "require_scenario_contract = false\n")
    assert main(["--check", "--root", str(tmp_path), "-q"]) == 1


# ---- toml_lite nested tables (the artifact-contract file shape) ---------

def test_toml_lite_nested_table_headers():
    doc = toml_lite.loads(textwrap.dedent("""\
        [a]
        x = 1
        [a.b]
        y = 2
        [a.b.c]
        z = "deep"
        """))
    assert doc == {"a": {"x": 1, "b": {"y": 2, "c": {"z": "deep"}}}}


def test_toml_lite_arrays_of_tables_nest():
    doc = toml_lite.loads(textwrap.dedent("""\
        [[unit]]
        name = "u1"
        [[unit.case]]
        tag = "a"
        [unit.case.measured.x32]
        flops = 1.5
        [[unit.case]]
        tag = "b"
        [unit.case.measured.x64]
        flops = 2.5
        [[unit]]
        name = "u2"
        """))
    units = doc["unit"]
    assert [u["name"] for u in units] == ["u1", "u2"]
    cases = units[0]["case"]
    assert [c["tag"] for c in cases] == ["a", "b"]
    # dotted headers attach to the LAST element of each table array
    assert cases[0]["measured"] == {"x32": {"flops": 1.5}}
    assert cases[1]["measured"] == {"x64": {"flops": 2.5}}
    assert "case" not in units[1]


def test_toml_lite_dotted_header_through_scalar_is_an_error():
    with pytest.raises(toml_lite.TomlError, match="not a table"):
        toml_lite.loads("[a]\nb = 1\n[a.b.c]\nd = 2\n")
    with pytest.raises(toml_lite.TomlError, match="empty table array"):
        toml_lite.loads("[a]\nb = []\n[a.b.c]\nd = 2\n")


def test_toml_lite_loads_the_committed_artifact_contracts():
    art = toml_lite.load(
        REPO / "src/repro/analysis/artifact_contracts.toml")["artifact"]
    assert art["schema_version"] == 1
    assert {u["name"] for u in art["unit"]} == \
        {"sweep_chunk", "run_sim", "ici_reactive"}
    sweep = next(u for u in art["unit"] if u["name"] == "sweep_chunk")
    case0 = sweep["case"][0]
    assert set(case0["measured"]) == {"x32", "x64"}
    assert case0["measured"]["x32"]["flops_per_scen"] > 0
    assert all(s["reason"].strip() for s in art["skip"])
