"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (the spec's required smoke)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step


def tiny_batch(cfg, key, B=2, T=16):
    if cfg.frontend == "audio_frames":
        return {
            "features": jax.random.normal(key, (B, T, cfg.d_model),
                                          cfg.dtype),
            "mask": jnp.ones((B, T), bool),
            "targets": jax.random.randint(key, (B, T), 0, cfg.vocab),
        }
    if cfg.frontend == "vision_patches":
        P = cfg.n_frontend_tokens
        return {
            "patches": jax.random.normal(key, (B, P, cfg.d_model),
                                         cfg.dtype),
            "tokens": jax.random.randint(key, (B, T - P), 0, cfg.vocab),
            "targets": jax.random.randint(key, (B, T - P), 0, cfg.vocab),
        }
    return {"tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
            "targets": jax.random.randint(key, (B, T), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = tiny_batch(cfg, key)
    opt_init, _ = make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg))
    p2, o2, m = step(params, opt, batch, jnp.zeros((), jnp.int32))
    assert jnp.isfinite(m["loss"]), arch
    assert jnp.isfinite(m["grad_norm"]), arch
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0, arch
    # output tree shapes preserved
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        assert a.shape == b.shape and a.dtype == b.dtype


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_shapes(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    batch = tiny_batch(cfg, key)
    batch.pop("targets", None)
    batch.pop("mask", None)
    logits, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(params, batch)
    assert logits.shape == (2, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits))
    assert cache["pos_offset"].shape == (2,)


def test_two_train_steps_reduce_loss_qwen():
    """A few steps on structured data must reduce the loss."""
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), vocab=128)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt_init, _ = make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=4))
    batch = tiny_batch(cfg, key, B=4, T=32)
    losses = []
    for i in range(8):
        params, opt, m = step(params, opt, batch, jnp.asarray(i, jnp.int32))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.2, losses
