"""Traffic generator: CDF fidelity vs published targets (paper Fig 7)."""
import jax
import numpy as np
import pytest

from repro.core.traffic import (TARGET_CDFS, TRAFFIC_SPECS,
                                pearson_vs_target, sample_flow_sizes,
                                sample_intervals)


@pytest.mark.parametrize("trace", list(TRAFFIC_SPECS))
def test_flow_size_cdf_matches_target(trace):
    """Paper reports Pearson r in 0.979-0.992 for flow sizes."""
    spec = TRAFFIC_SPECS[trace]
    sizes = sample_flow_sizes(jax.random.PRNGKey(0), spec, 200_000)
    r = pearson_vs_target(np.asarray(sizes), TARGET_CDFS[trace]["size"])
    assert r >= 0.95, f"{trace}: r={r:.4f}"


@pytest.mark.parametrize("trace", list(TRAFFIC_SPECS))
def test_interval_cdf_matches_target(trace):
    """Paper reports Pearson r in 0.894-0.998 for flow intervals."""
    spec = TRAFFIC_SPECS[trace]
    iat = sample_intervals(jax.random.PRNGKey(1), spec, 200_000)
    r = pearson_vs_target(np.asarray(iat), TARGET_CDFS[trace]["interval"])
    assert r >= 0.89, f"{trace}: r={r:.4f}"


def test_sampler_determinism():
    spec = TRAFFIC_SPECS["fb_web"]
    a = sample_flow_sizes(jax.random.PRNGKey(7), spec, 1000)
    b = sample_flow_sizes(jax.random.PRNGKey(7), spec, 1000)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sizes_positive_and_heavy_tailed():
    spec = TRAFFIC_SPECS["fb_hadoop"]
    s = np.asarray(sample_flow_sizes(jax.random.PRNGKey(0), spec, 100_000))
    assert (s > 0).all()
    assert np.median(s) < 10_000            # mice dominate
    assert np.quantile(s, 0.995) > 100_000  # elephants exist
