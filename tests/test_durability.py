"""Durable sweep execution (checkpoint/resume + retry policy):

* chunk-boundary snapshots are observation-only — a checkpointed run
  and a resume from a kill-at-chunk-k interruption are BIT-identical to
  the uninterrupted run (same PARITY_KEYS values, no new traces), with
  the host-transfer pin at exactly 1 + n_checkpoints;
* corrupt, truncated, or engine-mismatched checkpoints are rejected
  fail-fast with a structured ``CheckpointError`` naming the mismatch;
* ``BucketRetryPolicy`` sequences capped exponential backoff, the
  per-bucket deadline cuts retries (never finished work), and an
  exhausted bucket degrades to structured errors + a resumable salvage
  checkpoint while every other bucket's results come back intact;
* the whole resume contract holds under a sharded 4-device layout,
  including resuming a single-device checkpoint on four devices
  (subprocess leg; CI runs this file under both JAX_ENABLE_X64 modes).
"""
import dataclasses
import io
import json
from pathlib import Path

import numpy as np
import pytest

from repro.core import checkpoint as CK
from repro.core import simulator as S
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS
from tests._subproc import run_with_devices

TICKS, CHUNK = 240, 40          # 6 chunks; cadence-2 boundaries {2, 4}
SITE = FBSite(n_clusters=2, racks_per_cluster=3, servers_per_rack=4,
              csw_per_cluster=2, n_fc=2, csw_ring_links=2, fc_ring_links=4)
# every stateful mechanism rides the snapshot: fault timers, plane
# hazards, the flow table, plus a gating-off row and a knob-free row
KNOBS = dict(link_mtbf_ticks=400.0, repair_ticks=30, wake_fail_prob=0.05,
             plane_fail_prob=1e-3, flow_mode=1, rate_scale=1.5)


def _runs():
    spec = TRAFFIC_SPECS["fb_hadoop"]
    return [(S.SimParams(spec=spec, site=SITE, **KNOBS), 3),
            (S.SimParams(spec=spec, site=SITE, gating_enabled=False,
                         **KNOBS), 4),
            (S.SimParams(spec=spec, site=SITE), 5)]


def _batch():
    return S.make_batch(_runs())


def _spec(directory, **kw):
    kw.setdefault("every_chunks", 2)
    kw.setdefault("tag", "t")
    kw.setdefault("keep", 8)
    return CK.CheckpointSpec(directory=directory, **kw)


@pytest.fixture(scope="module")
def reference():
    """The uninterrupted run every parity test compares against
    (validate=True so the guard array rides the snapshots too)."""
    return S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK, validate=True)


@pytest.fixture(scope="module")
def ckpt_file(tmp_path_factory):
    """A real mid-run checkpoint (boundary 4 of 6) for the tamper and
    rejection tests to copy and mutate."""
    d = tmp_path_factory.mktemp("seed-ckpts")
    S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK, validate=True,
                checkpoint=_spec(d, tag="seed"))
    path = CK.latest_checkpoint(d, "seed")
    assert path is not None
    return path


# ---- checkpointed runs are observation-only -----------------------------

def test_checkpointed_run_bit_identical_with_pins(tmp_path, reference):
    """Cadenced snapshots change NOTHING about the run: bit-identical
    metrics, zero new traces, and exactly 1 + n_checkpoints transfers
    (cadence 2 over 6 chunks -> boundaries {2, 4}; the final boundary
    is never snapshotted)."""
    t0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
    res = S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK, validate=True,
                      checkpoint=_spec(tmp_path))
    assert S.TRACE_COUNT - t0 == 0
    assert S.HOST_TRANSFER_COUNT - h0 == 1 + 2
    assert [c for c, _ in CK.list_checkpoints(tmp_path, "t")] == [2, 4]
    diff, key = S.worst_parity(reference, res)
    assert diff == 0.0, key


def test_kill_at_chunk_k_then_resume_bit_identical(tmp_path, reference):
    """Preemption at the top of chunk 4: the boundary-4 snapshot was
    stashed but not yet written (deferred-by-one), so only boundary 2
    survives — and resuming it replays chunks 2..5 bit-identically in
    ONE further transfer."""
    def hook(ci):
        if ci == 4:
            raise RuntimeError("preempted")

    S.CHUNK_HOOK = hook
    try:
        with pytest.raises(RuntimeError, match="preempted"):
            S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK,
                        validate=True, checkpoint=_spec(tmp_path))
    finally:
        S.CHUNK_HOOK = None
    found = CK.list_checkpoints(tmp_path, "t")
    assert [c for c, _ in found] == [2]
    h0 = S.HOST_TRANSFER_COUNT
    res = S.resume_sweep(found[0][1])
    assert S.HOST_TRANSFER_COUNT - h0 == 1
    diff, key = S.worst_parity(reference, res)
    assert diff == 0.0, key


def test_resume_keeps_checkpointing_at_cadence(tmp_path, reference):
    """Passing a CheckpointSpec to resume_sweep continues snapshotting
    at the same ABSOLUTE chunk cadence (boundary 4 here), still
    bit-identically."""
    def hook(ci):
        if ci == 4:
            raise RuntimeError("preempted")

    S.CHUNK_HOOK = hook
    try:
        with pytest.raises(RuntimeError, match="preempted"):
            S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK,
                        validate=True, checkpoint=_spec(tmp_path))
    finally:
        S.CHUNK_HOOK = None
    h0 = S.HOST_TRANSFER_COUNT
    res = S.resume_sweep(CK.latest_checkpoint(tmp_path, "t"),
                         checkpoint=_spec(tmp_path))
    assert S.HOST_TRANSFER_COUNT - h0 == 1 + 1
    assert [c for c, _ in CK.list_checkpoints(tmp_path, "t")] == [2, 4]
    diff, key = S.worst_parity(reference, res)
    assert diff == 0.0, key


def test_prune_bounds_retained_files(tmp_path, reference):
    """keep=1 with a cadence of 1 leaves exactly the newest resumable
    boundary (5 of 6) on disk — and it still resumes bit-identically."""
    S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK, validate=True,
                checkpoint=_spec(tmp_path, every_chunks=1, keep=1))
    found = CK.list_checkpoints(tmp_path, "t")
    assert [c for c, _ in found] == [5]
    diff, key = S.worst_parity(reference, S.resume_sweep(found[0][1]))
    assert diff == 0.0, key


def test_host_fold_checkpoint_rejected(tmp_path):
    """The host-fold path synchronizes per chunk already; checkpointing
    it would pin a second fetch discipline, so it is an upfront error
    on both entry points."""
    with pytest.raises(ValueError, match="fold='device'"):
        S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK, fold="host",
                    checkpoint=_spec(tmp_path))
    with pytest.raises(ValueError, match="fold='device'"):
        S.run_sweep_planned(_runs(), TICKS, chunk_ticks=CHUNK,
                            fold="host", checkpoint=_spec(tmp_path))


def test_checkpoint_spec_validation():
    for kw in (dict(every_chunks=0), dict(every_chunks=1.5),
               dict(keep=0), dict(tag="bad/tag"), dict(tag="")):
        with pytest.raises(ValueError, match="CheckpointSpec"):
            CK.CheckpointSpec(**kw)
    assert CK.CheckpointSpec(tag="a", every_chunks=3).path_for(7).name \
        == "a-00000007.ckpt.npz"


# ---- corrupt / mismatched checkpoints fail fast -------------------------

def _rewritten(src, dst, mutate):
    """Copy a checkpoint applying ``mutate(meta, arrays)``; the rewrite
    restamps the content checksum, so what's probed is the ENGINE-level
    rejection in resume_sweep, not the file integrity layer."""
    meta, arrays = CK.read_checkpoint(src)
    mutate(meta, arrays)
    return CK.write_checkpoint(dst, meta, arrays)


def _drop_state_leaf(meta, arrays):
    name = next(n for n in sorted(arrays) if n.startswith("state"))
    del arrays[name]


def _reshape_state_leaf(meta, arrays):
    name = next(n for n in sorted(arrays) if n.startswith("state"))
    arrays[name] = np.repeat(arrays[name], 2, axis=0)


@pytest.mark.parametrize("reason,mutate", [
    ("sim_schema", lambda m, a: m.update(sim_schema=999)),
    ("fingerprint", lambda m, a: m.update(fault_knobs=m["fault_knobs"][:-1])),
    ("fingerprint", lambda m, a: m.update(flow_knobs=m["flow_knobs"] + ["ghost"])),
    ("scenario_fields",
     lambda m, a: m.update(scenario_fields=m["scenario_fields"] + ["ghost"])),
    ("x64_mode",
     lambda m, a: m.update(fold_dtype="float64" if m["fold_dtype"] == "float32"
                           else "float32")),
    ("state_schema", _drop_state_leaf),
    ("state_schema", _reshape_state_leaf),
], ids=["sim_schema", "fault_knobs", "flow_knobs", "scenario_fields",
        "x64_mode", "missing_leaf", "reshaped_leaf"])
def test_mismatched_checkpoint_rejected(tmp_path, ckpt_file, reason, mutate):
    bad = _rewritten(ckpt_file, tmp_path / "bad.ckpt.npz", mutate)
    with pytest.raises(CK.CheckpointError) as ei:
        S.resume_sweep(bad)
    assert ei.value.reason == reason
    assert "checkpoint rejected" in str(ei.value)


def test_truncated_checkpoint_rejected(tmp_path, ckpt_file):
    data = ckpt_file.read_bytes()
    bad = tmp_path / "trunc.ckpt.npz"
    bad.write_bytes(data[: len(data) // 2])
    with pytest.raises(CK.CheckpointError) as ei:
        S.resume_sweep(bad)
    assert ei.value.reason == "format"


def test_bitflipped_checkpoint_rejected(tmp_path, ckpt_file):
    """A single flipped byte surfaces at whichever integrity layer sees
    it first (the zip container or the content checksum) — never as a
    silent resume."""
    data = bytearray(ckpt_file.read_bytes())
    data[len(data) // 2] ^= 0xFF
    bad = tmp_path / "flip.ckpt.npz"
    bad.write_bytes(bytes(data))
    with pytest.raises(CK.CheckpointError) as ei:
        S.resume_sweep(bad)
    assert ei.value.reason in ("checksum", "format")


def test_stale_checksum_rejected(tmp_path, ckpt_file):
    """Tampered array contents under a stale stored checksum is exactly
    the class the content hash exists for."""
    meta, arrays = CK.read_checkpoint(ckpt_file)
    name = next(n for n in sorted(arrays) if n.startswith("fold_sum"))
    arrays[name] = arrays[name] + 1
    blob = io.BytesIO()
    np.savez(blob, **{CK._META_MEMBER: np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"),
        dtype=np.uint8)}, **arrays)
    bad = CK.atomic_write_bytes(tmp_path / "stale.ckpt.npz",
                                blob.getvalue())
    with pytest.raises(CK.CheckpointError) as ei:
        CK.read_checkpoint(bad)
    assert ei.value.reason == "checksum"


def test_wrong_ckpt_schema_rejected(tmp_path, ckpt_file):
    meta, arrays = CK.read_checkpoint(ckpt_file)
    meta["ckpt_schema"] = 999
    blob = io.BytesIO()
    np.savez(blob, **{CK._META_MEMBER: np.frombuffer(
        json.dumps(meta, sort_keys=True).encode("utf-8"),
        dtype=np.uint8)}, **arrays)
    bad = CK.atomic_write_bytes(tmp_path / "old.ckpt.npz", blob.getvalue())
    with pytest.raises(CK.CheckpointError) as ei:
        S.resume_sweep(bad)
    assert ei.value.reason == "ckpt_schema"


def test_atomic_write_leaves_no_temp_files(tmp_path):
    p = CK.atomic_write_text(tmp_path / "x.json", "{}")
    assert p.read_text() == "{}"
    assert [f.name for f in tmp_path.iterdir()] == ["x.json"]


# ---- retry policy: backoff, deadline, graceful degradation --------------

def _two_bucket_runs():
    site_b = FBSite(n_clusters=2, racks_per_cluster=5, servers_per_rack=4,
                    csw_per_cluster=2, n_fc=2, csw_ring_links=2,
                    fc_ring_links=4)
    spec = TRAFFIC_SPECS["fb_hadoop"]
    return [(S.SimParams(spec=spec, site=SITE), 0),
            (S.SimParams(spec=spec, site=site_b), 1),
            (S.SimParams(spec=spec, site=SITE, gating_enabled=False), 2)]


def test_backoff_schedule_and_policy_validation():
    p = S.BucketRetryPolicy(max_retries=4, backoff_base_s=0.25,
                            backoff_mult=2.0, backoff_max_s=0.6)
    assert [p.backoff_s(a) for a in (1, 2, 3, 4)] == [0.25, 0.5, 0.6, 0.6]
    # the default policy IS the original contract: one immediate retry
    d = S.BucketRetryPolicy()
    assert (d.max_retries, d.backoff_s(1), d.deadline_s) == (1, 0.0, None)
    for kw in (dict(max_retries=-1), dict(backoff_base_s=-0.1),
               dict(backoff_mult=0.5), dict(backoff_max_s=-1.0),
               dict(deadline_s=-2.0)):
        with pytest.raises(ValueError, match="BucketRetryPolicy"):
            S.BucketRetryPolicy(**kw)


def test_retry_backoff_sequence_and_structured_error(monkeypatch):
    """A permanently failing bucket is retried max_retries times with
    the capped exponential sleeps, then degrades to structured error
    entries while the other bucket's results return untouched."""
    sleeps, calls = [], []
    monkeypatch.setattr(S, "RETRY_SLEEP", sleeps.append)

    def hook(k, phase):
        calls.append((k, phase))
        if k == 0:
            raise RuntimeError("perma")

    monkeypatch.setattr(S, "BUCKET_FAIL_HOOK", hook)
    policy = S.BucketRetryPolicy(max_retries=3, backoff_base_s=0.25,
                                 backoff_mult=2.0, backoff_max_s=0.6)
    res = S.run_sweep_planned(_two_bucket_runs(), 160, max_compiles=2,
                              chunk_ticks=80, retry=policy)
    assert sleeps == [0.25, 0.5, 0.6]
    bad = [r for r in res if "error" in r]
    good = [r for r in res if "error" not in r]
    assert bad and good
    for r in bad:
        assert r["error"] == {"type": "RuntimeError", "message": "perma",
                              "stage": "dispatch", "retried": True}
    assert [c for c in calls if c[1] == "retry"] == [(0, "retry")] * 3
    assert all(r["injected_pkts"] > 0 for r in good)


def test_deadline_cuts_retries_not_results(monkeypatch):
    """deadline_s=0 abandons every retry (the bucket already spent its
    budget failing) but the OTHER bucket's finished work still comes
    back — deadlines bound retries, never completed results."""
    calls = []

    def hook(k, phase):
        calls.append((k, phase))
        if k == 0:
            raise RuntimeError("slow")

    monkeypatch.setattr(S, "BUCKET_FAIL_HOOK", hook)
    policy = S.BucketRetryPolicy(max_retries=5, deadline_s=0.0)
    res = S.run_sweep_planned(_two_bucket_runs(), 160, max_compiles=2,
                              chunk_ticks=80, retry=policy)
    bad = [r for r in res if "error" in r]
    assert bad
    for r in bad:
        assert r["error"]["retried"] is False
        # without checkpointing the error contract is exactly PR 6's
        assert sorted(r["error"]) == ["message", "retried", "stage", "type"]
    assert not [c for c in calls if c[1] == "retry"]
    assert [r for r in res if "error" not in r]


def test_degraded_bucket_leaves_resumable_salvage(tmp_path, monkeypatch):
    """With checkpointing on, an exhausted bucket that never reached a
    chunk boundary still leaves a chunk-0 salvage snapshot whose resume
    reproduces the bucket's clean results bit-identically."""
    runs = _two_bucket_runs()

    def hook(k, phase):
        if k == 0:
            raise RuntimeError("perma")

    monkeypatch.setattr(S, "BUCKET_FAIL_HOOK", hook)
    res = S.run_sweep_planned(
        runs, 160, max_compiles=2, chunk_ticks=80,
        checkpoint=_spec(tmp_path, tag="plan", every_chunks=1))
    bad = [r for r in res if "error" in r]
    good = [r for r in res if "error" not in r]
    assert bad and good
    ck = bad[0]["error"]["checkpoint"]
    assert ck is not None and Path(ck).name.endswith("-00000000.ckpt.npz")
    meta = CK.read_checkpoint(ck)[0]
    assert meta["plan"]["bucket"] == 0 and meta["plan"]["fingerprint"]
    # hook off: compare the salvage resume against a clean planned run
    monkeypatch.setattr(S, "BUCKET_FAIL_HOOK", None)
    resumed = S.resume_sweep(ck)
    clean = S.run_sweep_planned(runs, 160, max_compiles=2, chunk_ticks=80)
    by_label = {r["label"]: r for r in clean}
    ref = [by_label[r["label"]] for r in resumed]
    diff, key = S.worst_parity(ref, resumed)
    assert diff == 0.0, key


# ---- sharded layout (4 fake devices, subprocess) ------------------------

def test_resume_parity_under_sharding(tmp_path, reference):
    """The full kill/resume contract under a 4-device NamedSharding
    (3 real rows padded to 4), PLUS cross-layout portability: the
    single-device checkpoint written above resumes on four devices to
    the same bit-identical metrics."""
    # a 1-device-layout checkpoint + the reference metrics for it
    def hook(ci):
        if ci == 4:
            raise RuntimeError("preempted")

    S.CHUNK_HOOK = hook
    try:
        with pytest.raises(RuntimeError, match="preempted"):
            S.run_sweep(_batch(), TICKS, chunk_ticks=CHUNK,
                        validate=True, checkpoint=_spec(tmp_path))
    finally:
        S.CHUNK_HOOK = None
    one_dev_ckpt = CK.latest_checkpoint(tmp_path, "t")
    ref_path = tmp_path / "ref.json"
    ref_path.write_text(json.dumps(
        [{"label": r["label"],
          **{k: float(r[k]) for k in S.PARITY_KEYS}} for r in reference]))

    code = f"""
import json
from pathlib import Path
import jax
import pytest
from repro.core import checkpoint as CK
from repro.core import simulator as S
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

assert jax.local_device_count() == 4
TICKS, CHUNK = {TICKS}, {CHUNK}
SITE = FBSite(**{dataclasses.asdict(SITE)!r})
KNOBS = dict({KNOBS!r})
spec = TRAFFIC_SPECS["fb_hadoop"]
runs = [(S.SimParams(spec=spec, site=SITE, **KNOBS), 3),
        (S.SimParams(spec=spec, site=SITE, gating_enabled=False,
                     **KNOBS), 4),
        (S.SimParams(spec=spec, site=SITE), 5)]
batch = S.make_batch(runs)
reference = json.loads(Path({str(ref_path)!r}).read_text())

# leg 1: cross-layout — resume the 1-device checkpoint on 4 devices
res = S.resume_sweep({str(one_dev_ckpt)!r})
diff, key = S.worst_parity(reference, res)
assert diff == 0.0, ("cross-layout", key)

# leg 2: kill + checkpoint + resume entirely under the sharded layout
d = Path({str(tmp_path)!r}) / "sharded"
spec4 = CK.CheckpointSpec(directory=d, every_chunks=2, tag="s4", keep=8)
def hook(ci):
    if ci == 4:
        raise RuntimeError("preempted")
S.CHUNK_HOOK = hook
try:
    with pytest.raises(RuntimeError, match="preempted"):
        S.run_sweep(batch, TICKS, chunk_ticks=CHUNK, validate=True,
                    checkpoint=spec4)
finally:
    S.CHUNK_HOOK = None
found = CK.list_checkpoints(d, "s4")
assert [c for c, _ in found] == [2], found
h0 = S.HOST_TRANSFER_COUNT
res4 = S.resume_sweep(found[0][1])
assert S.HOST_TRANSFER_COUNT - h0 == 1
diff, key = S.worst_parity(reference, res4)
assert diff == 0.0, ("sharded", key)
print("SHARDED RESUME PARITY OK")
"""
    out = run_with_devices(code, n_devices=4)
    assert "SHARDED RESUME PARITY OK" in out
