"""Checkpointing + fault-tolerant trainer: atomicity, resume, failure
injection, straggler accounting, async save."""
import dataclasses
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore, save)
from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, batch_at, host_slice
from repro.train.trainer import SimulatedFailure, Trainer, TrainerConfig


def small_tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (17, 9)),
            "b": {"c": jax.random.normal(k2, (3,)),
                  "d": jnp.arange(5, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    tree = small_tree(jax.random.PRNGKey(0))
    save(tmp_path, tree, step=7)
    got, step = restore(tmp_path, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_k_retention(tmp_path):
    tree = small_tree(jax.random.PRNGKey(0))
    for s in range(6):
        save(tmp_path, tree, step=s, keep=2)
    dirs = sorted(p.name for p in tmp_path.iterdir() if p.is_dir())
    assert dirs == ["step_00000004", "step_00000005"]


def test_no_partial_checkpoint_visible(tmp_path):
    """A tmp dir must never be picked up by latest_step/restore."""
    tree = small_tree(jax.random.PRNGKey(0))
    save(tmp_path, tree, step=3)
    # simulate a crashed mid-write
    (tmp_path / ".tmp_step_00000009").mkdir()
    (tmp_path / "step_00000011").mkdir()      # no manifest -> incomplete
    assert latest_step(tmp_path) == 3


def test_async_checkpointer(tmp_path):
    tree = small_tree(jax.random.PRNGKey(1))
    ck = AsyncCheckpointer(tmp_path)
    ck.save_async(tree, 5)
    ck.wait()
    got, step = restore(tmp_path, tree)
    assert step == 5


def _trainer(tmp_path, total=12, fail_at=None, seed=0):
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), vocab=512)
    data = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4)
    tc = TrainerConfig(ckpt_dir=str(tmp_path), total_steps=total,
                       ckpt_every=4, fail_at_step=fail_at, seed=seed)
    return Trainer(cfg=cfg, tcfg=tc, data=data)


def test_failure_injection_and_bitwise_resume(tmp_path):
    # uninterrupted reference run
    ref = _trainer(tmp_path / "ref", total=12)
    ref.run()
    ref_losses = ref.losses()

    # run that dies at step 8, then restarts and resumes from step 8
    t1 = _trainer(tmp_path / "ft", total=12, fail_at=8)
    with pytest.raises(SimulatedFailure):
        t1.run()
    assert latest_step(tmp_path / "ft") == 8
    t2 = _trainer(tmp_path / "ft", total=12)
    t2.run()
    resumed = t2.losses()

    # steps 8..11 must match the uninterrupted run exactly
    np.testing.assert_allclose(resumed, ref_losses[8:], rtol=0, atol=0)


def test_straggler_flagging(tmp_path):
    t = _trainer(tmp_path, total=6)
    t.run()
    ms = t.metrics_log
    assert all("straggler" in m for m in ms)
    assert ms[-1]["stragglers_total"] <= len(ms)


def test_data_pipeline_deterministic_and_sharded():
    d = DataConfig(vocab=1000, seq_len=8, global_batch=8)
    a = batch_at(d, 3)
    b = batch_at(d, 3)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = batch_at(d, 4)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    s0 = host_slice(a, 0, 2)
    s1 = host_slice(a, 1, 2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]),
        np.asarray(a["tokens"]))
    assert (np.asarray(a["tokens"]) < 1000).all()
