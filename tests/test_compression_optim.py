"""Gradient compression (int8 + error feedback) and optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from repro.distributed.compression import (compress_grads, dequantize_int8,
                                           ef_state_init, quantize_int8)
from repro.optim import (adafactor_init, adafactor_update, adamw_init,
                         adamw_update)
from repro.optim.schedule import cosine_warmup


@given(st.integers(0, 1000))
def test_quantize_roundtrip_error_bound(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    q, s = quantize_int8(x)
    err = jnp.max(jnp.abs(dequantize_int8(q, s) - x))
    assert float(err) <= float(s) / 2 + 1e-6      # half-ULP bound


def test_error_feedback_preserves_sum():
    """With EF, the accumulated compressed gradients track the true sum."""
    key = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.fold_in(key, i), (32, 8))
              * 0.01} for i in range(50)]
    ef = ef_state_init(grads[0])
    acc_c = jnp.zeros((32, 8))
    acc_t = jnp.zeros((32, 8))
    for g in grads:
        cg, ef = compress_grads(g, ef)
        acc_c += cg["w"]
        acc_t += g["w"]
    # residual is bounded by one quantization step, not O(n_steps)
    resid = float(jnp.max(jnp.abs(acc_c - acc_t)))
    onestep = float(jnp.max(jnp.abs(jax.tree.leaves(ef)[0])))
    assert resid <= onestep + 1e-5


def _quadratic_losses(opt_init, opt_update, steps=60, lr=0.1):
    target = jnp.array([1.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = opt_init(params)
    losses = []
    for _ in range(steps):
        grads = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        params, state = opt_update(grads, state, params, lr,
                                   weight_decay=0.0)
        losses.append(float(jnp.sum((params["w"] - target) ** 2)))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw_init, adamw_update)
    assert losses[-1] < 1e-2 * losses[0]


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor_init, adafactor_update, lr=0.3)
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((128, 64)), "vec": jnp.zeros((16,))}
    st_ = adafactor_init(params)
    assert st_["v"]["big"]["vr"].shape == (128,)
    assert st_["v"]["big"]["vc"].shape == (64,)
    assert st_["v"]["vec"]["v"].shape == (16,)
    n_state = sum(x.size for x in jax.tree.leaves(st_))
    n_adam = 2 * sum(x.size for x in jax.tree.leaves(params))
    assert n_state < n_adam / 10


def test_cosine_warmup_shape():
    import numpy as np
    lrs = [float(cosine_warmup(jnp.asarray(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert np.argmax(lrs) <= 12
    assert lrs[-1] < 0.2
