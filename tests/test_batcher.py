"""Continuous batcher: outputs match direct decode; slots recycle."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as M
from repro.serving.batcher import ContinuousBatcher, Request


def _direct_greedy(cfg, params, prompt, n_new):
    logits, cache = M.prefill(cfg, params, {"tokens": jnp.asarray(
        prompt, jnp.int32)[None, :]})
    full = M.init_cache(cfg, 1, 64, dtype=cfg.dtype)

    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                sl = [slice(None)] * dst.ndim
                sl[ax] = slice(0, src.shape[ax])
                return dst.at[tuple(sl)].set(src)
        return src

    cache = jax.tree.map(merge, full, cache)
    out = [int(jnp.argmax(logits[0]))]
    tok = jnp.asarray([[out[-1]]], jnp.int32)
    for t in range(len(prompt), len(prompt) + n_new - 1):
        logits, cache = M.decode_step(cfg, params, cache, tok,
                                      jnp.full((1,), t, jnp.int32))
        out.append(int(jnp.argmax(logits[0])))
        tok = jnp.asarray([[out[-1]]], jnp.int32)
    return out


def test_batcher_matches_direct_decode():
    cfg = reduced(get_config("qwen3-0.6b"))
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    prompts = [[5, 9, 2, 7], [11, 3, 1, 8, 6, 2], [4, 4, 4]]
    n_new = 6

    b = ContinuousBatcher(cfg, params, n_slots=2, max_len=64)
    reqs = [Request(rid=i, tokens=p, max_new=n_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        b.submit(r)
    b.run(max_ticks=200)

    for r in reqs:
        assert r.done
        expect = _direct_greedy(cfg, params, r.tokens, n_new)
        assert r.out == expect, (r.rid, r.out, expect)


def test_batcher_slot_reuse_and_idle_tracking():
    cfg = reduced(get_config("qwen3-0.6b"))
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    # 4 requests through a single slot: forces sequential slot reuse
    b = ContinuousBatcher(cfg, params, n_slots=1, max_len=32)
    reqs = [Request(rid=i, tokens=[i + 1, i + 2], max_new=3)
            for i in range(4)]
    for r in reqs:
        b.submit(r)
    b.run(max_ticks=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
    # idle ticks only after the queue drains
    assert 0.0 <= b.idle_fraction() < 1.0
