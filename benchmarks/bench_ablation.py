"""Ablation: watermark thresholds and the anti-flap dwell (paper Sec V:
"experimentally determined to balance energy savings with network
performance"). hi/lo/dwell are array-valued scenario knobs, so the whole
ablation grid runs as ONE batched sweep (one compile).

  PYTHONPATH=src python -m benchmarks.bench_ablation
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.simulator import SimParams, make_batch, run_sweep
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "ablation.json"
TICKS = 30_000
TRACE = "fb_hadoop"


def main():
    spec = TRAFFIC_SPECS[TRACE]
    trials = [("always-on baseline", {"gating_enabled": False}),
              ("hi75/lo22 (paper)", {}),
              # threshold sensitivity
              ("hi50/lo22", {"hi": 0.50}),
              ("hi90/lo22", {"hi": 0.90}),
              ("hi75/lo10", {"lo": 0.10}),
              ("hi75/lo40", {"lo": 0.40})]
    # dwell ablation: flapping cost (DESIGN.md deviation note)
    trials += [(f"dwell={d}us", {"dwell": d})
               for d in (0, 64, 256, 1024, 4096)]

    res = run_sweep(make_batch(
        [(SimParams(spec=spec, **kw), 0) for _, kw in trials]), TICKS)
    base = res[0]
    print(f"trace={TRACE}, {TICKS} ticks, baseline latency "
          f"{base['mean_latency_us']:.2f} us "
          f"({len(trials)} scenarios, one compile)")
    rows = []
    for (tag, kw), r in zip(trials[1:], res[1:]):
        pen = r["mean_latency_us"] / base["mean_latency_us"] - 1
        rows.append({"tag": tag, **kw,
                     "savings": r["switch_energy_savings_frac"],
                     "penalty": pen})
        print(f"{tag:28s} savings={r['switch_energy_savings_frac']:.3f} "
              f"penalty={pen*100:+.1f}%")

    OUT.write_text(json.dumps(rows, indent=1))
    print(f"written: {OUT}")


if __name__ == "__main__":
    main()
