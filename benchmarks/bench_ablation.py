"""Ablation: watermark thresholds and the anti-flap dwell (paper Sec V:
"experimentally determined to balance energy savings with network
performance").

  PYTHONPATH=src python -m benchmarks.bench_ablation
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.core.simulator import SimParams, run_sim
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "ablation.json"
TICKS = 30_000
TRACE = "fb_hadoop"


def main():
    import repro.core.constants as C
    spec = TRAFFIC_SPECS[TRACE]
    base = run_sim(SimParams(spec=spec, gating_enabled=False), TICKS, 0)
    rows = []

    def trial(tag, **kw):
        r = run_sim(SimParams(spec=spec, **kw), TICKS, 0)
        pen = r["mean_latency_us"] / base["mean_latency_us"] - 1
        rows.append({"tag": tag, **kw,
                     "savings": r["switch_energy_savings_frac"],
                     "penalty": pen})
        print(f"{tag:28s} savings={r['switch_energy_savings_frac']:.3f} "
              f"penalty={pen*100:+.1f}%")

    print(f"trace={TRACE}, {TICKS} ticks, baseline latency "
          f"{base['mean_latency_us']:.2f} us")
    # paper watermarks
    trial("hi75/lo22 (paper)")
    # threshold sensitivity
    trial("hi50/lo22", hi=0.50)
    trial("hi90/lo22", hi=0.90)
    trial("hi75/lo10", lo=0.10)
    trial("hi75/lo40", lo=0.40)

    # dwell ablation: flapping cost (DESIGN.md deviation note)
    for dwell in (0, 64, 256, 1024, 4096):
        trial(f"dwell={dwell}us", dwell=dwell)

    OUT.write_text(json.dumps(rows, indent=1))
    print(f"written: {OUT}")


if __name__ == "__main__":
    main()
