"""Fig-1-style design comparison on the dynamic simulator: several
FBSite fabric shapes (same server population, different cluster / plane
/ core structure), each with LC/DC gating and the always-on baseline,
run through the hull-bucketing sweep planner — a handful of vmapped
compiles (``--max-compiles``, one per hull bucket, remainder tails
included) instead of one compile on the worst-case padded hull. The
buckets execute as an async pipeline (all chunk programs dispatched
before any result is fetched; ``--no-pipeline`` for strictly serial
buckets) with the device-resident fold's one-host-transfer-per-bucket
contract enforced.

This is the dynamic companion to topology.all_designs() (the paper's
static Fig 1 component-count power table, also printed for context):
instead of peak component power it reports what the watermark controller
actually achieves on each fabric shape under the same traffic.

  PYTHONPATH=src python -m benchmarks.bench_multi_site           # 20k us
  PYTHONPATH=src python -m benchmarks.bench_multi_site --smoke   # canary

--check additionally re-runs every scenario single-site and asserts the
PARITY_KEYS agree within --tol (the padding-is-inert contract, now per
bucket). --max-compiles 1 recovers the old single-hull path exactly.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core import simulator as S
from repro.core.topology import FBSite, all_designs
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / \
    "bench_multi_site.json"

# same 128 racks x 48 servers, three fabric shapes: the Fig 2 default,
# a wide two-cluster build (fewer, fatter clusters), and a dense
# eight-cluster build (more, thinner clusters with 2 planes / 2 FCs)
SITES = {
    "fb_clos_4x32": FBSite(),
    "wide_2x64": FBSite(n_clusters=2, racks_per_cluster=64,
                        csw_per_cluster=4, n_fc=4),
    "dense_8x16": FBSite(n_clusters=8, racks_per_cluster=16,
                         csw_per_cluster=2, n_fc=2,
                         csw_ring_links=4, fc_ring_links=8),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--trace", default="fb_hadoop",
                    choices=sorted(TRAFFIC_SPECS))
    ap.add_argument("--smoke", action="store_true",
                    help="short run, <1 min, for use as a CI canary")
    ap.add_argument("--check", action="store_true",
                    help="verify parity against single-site run_sweep")
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-compiles", type=int, default=2,
                    help="planner hull-bucket budget (1 = old single-hull)")
    ap.add_argument("--no-pipeline", action="store_true",
                    help="run hull buckets strictly serially instead of "
                         "async-dispatching them all before fetching")
    args = ap.parse_args()

    # deliberately NOT a multiple of the chunk: the remainder tail must
    # ride the same compiled chunk program (live-tick mask)
    ticks = args.ticks or (1_000 if args.smoke else 20_000)
    chunk = 400 if args.smoke else 8_192

    spec = TRAFFIC_SPECS[args.trace]
    runs = [(S.SimParams(spec=spec, site=site, gating_enabled=g), 0)
            for site in SITES.values() for g in (True, False)]
    print(f"{len(SITES)} sites x {{lcdc, base}} = {len(runs)} scenarios, "
          f"trace={args.trace}, {ticks} ticks (chunk {chunk}), "
          f"max_compiles={args.max_compiles}")

    n0, h0 = S.TRACE_COUNT, S.HOST_TRANSFER_COUNT
    t0 = time.time()
    res, plan = S.run_sweep_planned(runs, ticks, chunk_ticks=chunk,
                                    max_compiles=args.max_compiles,
                                    return_plan=True,
                                    pipeline=not args.no_pipeline)
    wall = time.time() - t0
    traces = S.TRACE_COUNT - n0
    transfers = S.HOST_TRANSFER_COUNT - h0
    how = ("serial buckets" if args.no_pipeline else
           f"async pipeline, dispatch order {plan['dispatch_order']}")
    print(f"planned multi-site sweep: {wall:.2f} s ({how}), "
          f"step traces: {traces} "
          f"(contract: one per hull bucket = {plan['n_buckets']}, "
          f"remainder tails included), host transfers: {transfers} "
          f"(contract: one fold fetch per bucket)")
    if traces != plan["n_buckets"]:
        raise SystemExit("one-compile-per-bucket contract broken: "
                         f"{traces} traces for {plan['n_buckets']} buckets")
    if transfers > plan["n_buckets"]:
        raise SystemExit("one-transfer-per-bucket contract broken: "
                         f"{transfers} host transfers for "
                         f"{plan['n_buckets']} buckets")

    print(f"\n--- hull-bucket plan (padded-compute savings "
          f"{plan['savings_vs_single_hull_frac']:.1%} vs single hull) ---")
    for b in plan["buckets"]:
        print(f"hull {b['hull']:22s} x{b['n_scenarios']} scenarios  "
              f"waste {b['waste_frac']:6.1%}  indices {b['indices']}")

    print("\n--- static Fig 1 context (peak component power, kW) ---")
    for d in all_designs():
        kw = sum(d.network_power_w().values()) / 1e3
        print(f"{d.name:22s} {kw:8.1f} kW   ({d.notes})")

    print("\n--- dynamic LC/DC comparison (this sweep) ---")
    rows = []
    for i, (name, site) in enumerate(SITES.items()):
        lc, base = res[2 * i], res[2 * i + 1]
        pen = lc["mean_latency_us"] / base["mean_latency_us"] - 1.0
        rows.append({
            "site": name, "label": lc["label"],
            "gated_links": site.n_rsw_csw_links + site.n_csw_fc_links,
            "peak_transceiver_w": site.total_transceiver_power_w(),
            "switch_energy_savings_frac":
                lc["switch_energy_savings_frac"],
            "all_transceiver_savings_frac":
                lc["all_transceiver_savings_frac"],
            "transceiver_power_w": lc["transceiver_power_w"],
            "mean_latency_us": lc["mean_latency_us"],
            "latency_penalty": pen,
            "half_off_frac": lc["half_off_frac"],
        })
        print(f"{name:14s} savings={lc['switch_energy_savings_frac']:.3f} "
              f"(all-transceiver {lc['all_transceiver_savings_frac']:.3f}) "
              f"latency {lc['mean_latency_us']:6.2f} us "
              f"({pen*100:+.1f}%) half-off {lc['half_off_frac']:.0%}")

    worst_key, worst = None, 0.0
    if args.check:
        singles = [S.run_sweep(S.make_batch([run]), ticks,
                               chunk_ticks=chunk)[0] for run in runs]
        worst, worst_key = S.worst_parity(singles, res)
        ok = worst <= args.tol
        print(f"\nmax multi-vs-single-site rel diff: {worst:.2e} "
              f"[{worst_key}] {'OK' if ok else f'> tol {args.tol:g}'}")
        if not ok:
            raise SystemExit(1)

    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps({
        "smoke": args.smoke, "trace": args.trace, "ticks": ticks,
        "chunk_ticks": chunk, "scenarios": len(runs),
        "step_traces": traces, "host_transfers": transfers,
        "pipelined": not args.no_pipeline, "exec": S.execution_mode(),
        "wall_s": round(wall, 3),
        "checked": bool(args.check), "max_rel_diff": worst,
        "plan": plan,
        "sites": rows,
    }, indent=1))
    print(f"written: {OUT}")


if __name__ == "__main__":
    main()
