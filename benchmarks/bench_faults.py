"""Optical fault-injection benchmark: the savings-vs-availability
frontier, plus the CI correctness gate for the fault subsystem.

One batched sweep (a single compile: every fault knob is a ``Scenario``
array leaf) runs a grid of fault-severity levels x operating modes —
LC/DC gating with the connectivity-preserving fallback, LC/DC with the
fallback disabled (the ablation), and the always-on baseline — and
reports, per severity level, the energy savings the gating still
achieves against what the faults cost in availability: delivered
fraction, fault-drop fraction, connectivity-loss ticks, wake retries /
forced wakes, and the fault-stall delay attribution.

The run doubles as the fault-model regression gate (``--check-baseline``
against the ``bench_faults`` section of benchmarks/baselines.json, the
CI fault-canary job):

  * zero-fault rows report every fault metric as EXACTLY zero (the
    fault model must be inert when disabled — the bit-parity contract),
  * packet conservation holds with the fault-drop bin included
    (injected == delivered + drops + fault_drops + in-flight),
  * with the fallback enabled no valid switch ever loses its last
    usable uplink (conn_loss_ticks == 0); with it disabled, it does,
  * with gating disabled the fault-stall attribution and wake
    retry/fallback counters are exactly zero (stage-up never happens),
  * the whole grid stays ONE compile, and a ``validate=True`` pass of
    the same batch (in-program finite + conservation guards) is clean.

Every band is machine-independent (abs bounds / exact pins), so one
blessed section covers both JAX_ENABLE_X64 modes — the canary runs the
gate under both without re-blessing.

  PYTHONPATH=src python -m benchmarks.bench_faults             # full
  PYTHONPATH=src python -m benchmarks.bench_faults --smoke     # canary
  PYTHONPATH=src python -m benchmarks.bench_faults --smoke --check-baseline
  PYTHONPATH=src python -m benchmarks.bench_faults --smoke --update-baseline

``--check-baseline`` merges this bench's record into the PR's
``BENCH_<n>.json`` trajectory file under the ``bench_faults`` key.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks import baseline_gate as BG
from repro.core import simulator as S
from repro.core.simulator import SimParams, make_batch, run_sweep
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

RESULTS = Path(__file__).resolve().parents[1] / "results"
OUT = RESULTS / "bench_faults.json"

#: fault-severity levels:
#:   (wake_fail_prob, wake_jitter_frac, link_mtbf_ticks, repair_ticks)
LEVELS = {
    "none": (0.0, 0.0, 0, 0),
    "mild": (0.05, 0.25, 50_000, 200),
    "harsh": (0.30, 0.50, 5_000, 400),
}

#: every fault metric that must be EXACTLY zero when the knobs are zero
ZERO_FAULT_METRICS = (
    "fault_drop_frac", "fault_dropped_pkts", "wake_retries",
    "forced_wakes", "conn_loss_ticks", "link_fault_frac",
    "delay_fault_stall_us", "fault_stall_frac",
)

#: machine-independent bands only — one bless covers both x64 modes
DEFAULT_BANDS = {
    # the fault model must be inert at zero knobs (bit-parity contract)
    "faults_zero_rows_max_metric": {"max_abs": 0.0},
    # conservation with the fault-drop bin, worst row over the grid
    "faults_conservation_rel_err": {"max_abs": 1e-3},
    # min-connectivity invariant: fallback on -> no switch ever loses
    # its last usable uplink; the no-fallback ablation must actually
    # lose connectivity under harsh faults (else the invariant test is
    # vacuous)
    "faults_fallback_conn_loss_ticks": {"max_abs": 0.0},
    "faults_nofb_conn_loss_ticks": {"min_abs": 1.0},
    # gating disabled -> stage-up never happens: no retries, no forced
    # wakes, no fault-stall attribution
    "faults_gating_off_stall": {"max_abs": 0.0},
    # harsh faults degrade availability but must not collapse it
    "faults_harsh_delivered_frac": {"min_abs": 0.5},
    # the whole grid is one vmapped batch: one compile, and the
    # validate=True pass (its own program) must come back clean
    "faults_traces": {"equal": True},
    "faults_validate_clean": {"equal": True},
}


def _grid_runs(site: FBSite):
    """(label, SimParams, seed) rows: severity levels x operating
    modes, all on one site so the grid is one ``make_batch`` compile."""
    spec = TRAFFIC_SPECS["fb_hadoop"]
    rows = []
    for lvl, (wfp, wjf, mtbf, rep) in LEVELS.items():
        # rate_scale 1.6: enough load that watermark-driven stage churn
        # actually happens — wake events are what the transient-failure
        # and jitter knobs act on; at 1.0 the stage barely moves and
        # the wake-retry path would go unexercised
        knobs = dict(rate_scale=1.6, wake_fail_prob=wfp,
                     wake_jitter_frac=wjf, link_mtbf_ticks=mtbf,
                     repair_ticks=rep)
        rows.append((lvl, "lcdc", SimParams(
            spec=spec, site=site, gating_enabled=True, **knobs)))
        rows.append((lvl, "lcdc-nofb", SimParams(
            spec=spec, site=site, gating_enabled=True,
            fault_fallback=False, **knobs)))
        rows.append((lvl, "base", SimParams(
            spec=spec, site=site, gating_enabled=False, **knobs)))
    return rows


def bench_faults(args) -> dict:
    site = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                  csw_per_cluster=2, n_fc=2, csw_ring_links=4,
                  fc_ring_links=8) if args.smoke else FBSite()
    ticks = args.ticks or (2_000 if args.smoke else 20_000)
    chunk = max(1, ticks // 4)          # force a multi-chunk run
    rows = _grid_runs(site)
    # per-row seeds keep every scenario label unique in the batch
    batch = make_batch([(p, i) for i, (_, _, p) in enumerate(rows)])
    print(f"fault grid: {len(LEVELS)} severity levels x "
          f"{{lcdc, lcdc-nofb, base}} = {len(rows)} scenarios, "
          f"{ticks} ticks (chunk {chunk})")

    n0 = S.TRACE_COUNT
    t0 = time.time()
    res, state = run_sweep(batch, ticks, chunk_ticks=chunk,
                           return_state=True)
    t_grid = time.time() - t0
    traces = S.TRACE_COUNT - n0

    # conservation per row, fault-drop bin included (state-level audit)
    cons = []
    for i, r in enumerate(res):
        in_flight = sum(float(np.sum(np.asarray(q)[i]))
                        for q in (state.rsw_q, state.csw_up_q,
                                  state.csw_down_q, state.fc_down_q))
        inj = r["injected_pkts"]
        err = inj - (r["delivered_pkts"] + r["drop_frac"] * inj
                     + r["fault_dropped_pkts"] + in_flight)
        cons.append(abs(err) / max(inj, 1e-9))

    # the validate=True pass: same batch, in-program guards (this is a
    # second compile by design — the guard changes the chunk program)
    try:
        run_sweep(batch, min(ticks, 2 * chunk), chunk_ticks=chunk,
                  validate=True)
        validate_clean = 1
    except S.SweepValidationError as exc:
        print(f"validate=True pass FAILED: {exc}")
        validate_clean = 0

    by = {(lvl, mode): r for (lvl, mode, _), r in zip(rows, res)}
    zero_rows_max = max(
        abs(by["none", m][k])
        for m in ("lcdc", "lcdc-nofb", "base") for k in ZERO_FAULT_METRICS)
    gating_off_stall = max(
        abs(by[lvl, "base"][k])
        for lvl in LEVELS
        for k in ("fault_stall_frac", "delay_fault_stall_us",
                  "wake_retries", "forced_wakes"))
    fb_conn = max(by[lvl, "lcdc"]["conn_loss_ticks"] for lvl in LEVELS)
    nofb_conn = by["harsh", "lcdc-nofb"]["conn_loss_ticks"]

    print(f"\n{'level':8s} {'mode':10s} {'savings':>8s} {'deliv':>7s} "
          f"{'fdrop':>8s} {'connloss':>8s} {'retries':>8s} "
          f"{'forced':>7s} {'fstall_us':>9s}")
    frontier = []
    for lvl in LEVELS:
        for mode in ("lcdc", "lcdc-nofb", "base"):
            r = by[lvl, mode]
            print(f"{lvl:8s} {mode:10s} "
                  f"{r['all_transceiver_savings_frac']:8.1%} "
                  f"{r['delivered_frac']:7.3f} "
                  f"{r['fault_drop_frac']:8.2e} "
                  f"{r['conn_loss_ticks']:8.0f} {r['wake_retries']:8.0f} "
                  f"{r['forced_wakes']:7.0f} "
                  f"{r['delay_fault_stall_us']:9.4f}")
            frontier.append({
                "level": lvl, "mode": mode,
                "savings_frac": r["all_transceiver_savings_frac"],
                "delivered_frac": r["delivered_frac"],
                "fault_drop_frac": r["fault_drop_frac"],
                "conn_loss_ticks": r["conn_loss_ticks"],
                "wake_retries": r["wake_retries"],
                "forced_wakes": r["forced_wakes"],
                "delay_fault_stall_us": r["delay_fault_stall_us"],
                "link_fault_frac": r["link_fault_frac"],
            })

    return {
        "ticks": ticks, "scenarios": len(rows), "t_grid_s": round(t_grid, 3),
        "faults_traces": traces,
        "faults_zero_rows_max_metric": zero_rows_max,
        "faults_conservation_rel_err": max(cons),
        "faults_fallback_conn_loss_ticks": fb_conn,
        "faults_nofb_conn_loss_ticks": nofb_conn,
        "faults_gating_off_stall": gating_off_stall,
        "faults_harsh_delivered_frac": by["harsh", "lcdc"][
            "delivered_frac"],
        "faults_validate_clean": validate_clean,
        "frontier": frontier,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small site + short run, the CI fault canary")
    ap.add_argument("--check-baseline", action="store_true",
                    help="gate against the bench_faults baseline section")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless this run's values into baselines.json")
    args = ap.parse_args()

    results = {"smoke": args.smoke, "exec": S.execution_mode()}
    results.update(bench_faults(args))

    out = OUT.with_name("bench_faults_smoke.json") if args.smoke else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"written: {out}")

    mode = "smoke" if args.smoke else "full"
    sane = (results["faults_zero_rows_max_metric"] == 0.0
            and results["faults_conservation_rel_err"] <= 1e-3
            and results["faults_validate_clean"] == 1)
    if args.update_baseline:
        if not sane:
            raise SystemExit("refusing to bless baseline: this run "
                             "failed its own fault-model checks")
        bands = DEFAULT_BANDS
        prev = BG.load_section("bench_faults")
        if prev is not None and prev.get("mode") == mode:
            bands = {**DEFAULT_BANDS, **prev.get("bands", {})}
        missing = [k for k in bands if k not in results]
        if missing:
            raise SystemExit("refusing to bless baseline: banded "
                             f"metrics missing from this run: {missing}")
        BG.bless_section("bench_faults", mode,
                         {k: results[k] for k in bands}, bands)
        print(f"baseline blessed: {BG.BASELINE}")

    if args.check_baseline:
        baseline = BG.load_section("bench_faults")
        if baseline is None:
            raise SystemExit(f"no bench_faults baseline at {BG.BASELINE}; "
                             "bless one with --update-baseline and "
                             "commit it")
        if baseline.get("mode") != mode:
            raise SystemExit(
                f"baseline was blessed in {baseline.get('mode')!r} mode "
                f"but this run is {mode!r}; re-bless or match modes")
        print(f"\nbaseline gate ({BG.BASELINE.name}, mode={mode}):")
        fails = BG.check_bands(results, baseline)
        trajectory = BG.merge_trajectory("bench_faults", {
            "mode": mode, "gate": "failed" if fails else "passed",
            "exec": results["exec"],
            "checks": {k: results[k] for k in DEFAULT_BANDS},
            "frontier": results["frontier"],
            "timings_s": {"grid": results["t_grid_s"]},
        })
        print(f"trajectory record written: {trajectory}")
        if fails:
            raise SystemExit("baseline gate FAILED:\n  "
                             + "\n  ".join(fails))
        print("baseline gate passed")
    elif not sane:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
