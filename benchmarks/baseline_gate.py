"""Shared perf/parity baseline machinery for the benchmark gates.

One committed baseline file (benchmarks/baselines.json) holds a SECTION
per benchmark::

    {"schema": 2,
     "bench_sweep":  {"mode": "smoke", "values": {...}, "bands": {...}},
     "bench_faults": {"mode": "smoke", "values": {...}, "bands": {...}}}

so each gate (`bench_sweep`, `bench_faults`, ...) blesses and checks its
own values without clobbering the others. Schema-1 files (the pre-PR-6
flat layout, which only ever held bench_sweep's values) are read
transparently as a lone ``bench_sweep`` section and upgraded in place on
the next bless.

Band types (per metric, any combination):

  max_abs / min_abs          machine-independent hard bounds
  max_frac_of_baseline /     generous ratios to the blessed value
  min_frac_of_baseline       (CI-noise tolerant; catch order-of-magnitude
                             regressions, not 10% jitter)
  equal                      exact match against the blessed value

A blessed-relative band whose blessed value is missing fails loudly —
a renamed metric or hand-edit must not silently disable a gate.

The perf-trajectory record (``BENCH_<n>.json`` at the repo root, n = the
PR index derived from CHANGES.md) is shared too: each gate merges its
record under its own key, so one PR's record carries every benchmark
that ran.
"""
from __future__ import annotations

import json
from pathlib import Path

# atomic temp+rename writes: an interrupted bench run must never leave
# a truncated committed baseline or trajectory record behind
from repro.core.checkpoint import atomic_write_text

BASELINE = Path(__file__).resolve().with_name("baselines.json")
CHANGES = Path(__file__).resolve().parents[1] / "CHANGES.md"


def pr_index() -> int:
    """The current PR number, derived from CHANGES.md (one `- PR n:`
    line per landed PR) — keeps the BENCH_<n>.json trajectory record
    self-labeling so future PRs append to the trajectory instead of
    overwriting this one's record with a stale label."""
    try:
        return sum(1 for ln in CHANGES.read_text().splitlines()
                   if ln.startswith("- PR"))
    except OSError:
        return 0


def trajectory_path() -> Path:
    return CHANGES.with_name(f"BENCH_{pr_index()}.json")


def merge_trajectory(bench: str, record: dict) -> Path:
    """Merge one benchmark's record into this PR's BENCH_<n>.json under
    its own key (written even on gate failure: the trajectory should
    record regressions, not hide them)."""
    path = trajectory_path()
    data = {}
    if path.exists():
        try:
            data = json.loads(path.read_text())
        except ValueError:
            data = {}
    data["pr"] = pr_index()
    data[bench] = record
    atomic_write_text(path, json.dumps(data, indent=1) + "\n")
    return path


def _load_all() -> dict:
    """The baseline file as schema-2 sections (schema-1 flat files are
    presented as a lone bench_sweep section)."""
    if not BASELINE.exists():
        return {"schema": 2}
    data = json.loads(BASELINE.read_text())
    if data.get("schema") == 1:
        return {"schema": 2,
                "bench_sweep": {k: data[k] for k in
                                ("mode", "values", "bands") if k in data}}
    return data


def load_section(bench: str) -> dict | None:
    return _load_all().get(bench)


def bless_section(bench: str, mode: str, values: dict,
                  bands: dict) -> None:
    """Write one benchmark's blessed values/bands, preserving every
    other section (and upgrading schema-1 files in place)."""
    data = _load_all()
    data["schema"] = 2
    data[bench] = {"mode": mode, "values": values, "bands": bands}
    atomic_write_text(BASELINE, json.dumps(data, indent=1) + "\n")


def check_bands(current: dict, section: dict) -> list:
    """Compare a run against a blessed section; returns failures."""
    fails = []
    for key, bands in section["bands"].items():
        if key not in current:
            fails.append(f"{key}: missing from current run")
            continue
        cur = current[key]
        base = section["values"].get(key)
        for btype, bval in bands.items():
            if btype == "max_abs":
                ok, want = cur <= bval, f"<= {bval:g}"
            elif btype == "min_abs":
                ok, want = cur >= bval, f">= {bval:g}"
            elif btype == "min_frac_of_baseline":
                ok = base is not None and cur >= base * bval
                want = f">= {bval:g} x blessed {base}"
            elif btype == "max_frac_of_baseline":
                ok = base is not None and cur <= base * bval
                want = f"<= {bval:g} x blessed {base}"
            elif btype == "equal":
                ok = base is not None and cur == base
                want = f"== blessed {base}"
            else:
                ok, want = False, f"unknown band type {btype!r}"
            status = "PASS" if ok else "FAIL"
            print(f"  [{status}] {key} = {cur} (want {want})")
            if not ok:
                fails.append(f"{key}={cur} violates {btype} ({want})")
    return fails
