"""Flow-level workload benchmark: the FCT-slowdown frontier of LC/DC
gating under heavy-tailed DCN workloads, plus the CI correctness gate
for the flow engine.

One batched sweep (a single compile: every flow knob is a ``Scenario``
array leaf) runs a grid of workloads x operating modes — the websearch
and datamining flow-size distributions at light and loaded arrival
rates, LC/DC gating vs the always-on baseline, an incast row that
saturates a shrunken flow table, and a pair of ``flow_mode=0`` rows —
and reports, per row, the energy savings the gating still achieves
against what it costs in flow completion time: per-size-class FCT
p50/p99 and slowdown vs the ideal-bandwidth baseline.

The run doubles as the flow-model regression gate (``--check-baseline``
against the ``bench_flows`` section of benchmarks/baselines.json, the
CI flow-canary job):

  * ``flow_mode=0`` rows report every flow metric as EXACTLY zero (the
    flow engine must be inert when disabled — the bit-parity contract),
  * flow conservation is EXACT in every row, eviction included
    (started == completed + evicted + still-in-table),
  * every slowdown percentile is >= 1 (emission is capped at line rate
    and path samples are >= the unloaded path, so FCT >= ideal FCT),
  * the incast row actually evicts (table pressure is exercised, not
    vacuous) while its conservation census still closes exactly,
  * the whole grid stays ONE compile, and a ``validate=True`` pass of
    the same batch (in-program finite + conservation + flow-census
    guards) is clean.

Every band is machine-independent (abs bounds / exact pins), so one
blessed section covers both JAX_ENABLE_X64 modes — the canary runs the
gate under both without re-blessing.

  PYTHONPATH=src python -m benchmarks.bench_flows              # full
  PYTHONPATH=src python -m benchmarks.bench_flows --smoke      # canary
  PYTHONPATH=src python -m benchmarks.bench_flows --smoke --check-baseline
  PYTHONPATH=src python -m benchmarks.bench_flows --smoke --update-baseline

``--check-baseline`` merges this bench's record into the PR's
``BENCH_<n>.json`` trajectory file under the ``bench_flows`` key.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks import baseline_gate as BG
from repro.core import simulator as S
from repro.core import workloads
from repro.core.simulator import SimParams, make_batch, run_sweep
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

RESULTS = Path(__file__).resolve().parents[1] / "results"
OUT = RESULTS / "bench_flows.json"

#: flow-workload levels: (flow_size_dist, flow_arrival_rate) — rates
#: are per-rack per-tick arrival probabilities, chosen so "light" rows
#: drain (FCT frontier is meaningful) and "loaded" rows queue
LEVELS = {
    "web-light": ("websearch", 0.02),
    "web-loaded": ("websearch", 0.08),
    "dm-light": ("datamining", 0.02),
}

#: every scalar flow metric that must be EXACTLY zero at flow_mode=0
ZERO_FLOW_METRICS = tuple(
    ["flows_started", "flows_completed", "flows_evicted",
     "flow_evicted_frac", "fct_mean_us", "fct_slowdown_mean",
     "fct_p50_us", "fct_p99_us", "fct_slowdown_p50", "fct_slowdown_p99"]
    + [f"{stem}_{c}" for c in workloads.FLOW_CLASS_NAMES
       for stem in ("flows_completed", "fct_p50_us", "fct_p99_us",
                    "fct_slowdown_p50", "fct_slowdown_p99")])

#: machine-independent bands only — one bless covers both x64 modes
DEFAULT_BANDS = {
    # the flow engine must be inert at flow_mode=0 (bit-parity contract)
    "flows_zero_rows_max_metric": {"max_abs": 0.0},
    # exact flow conservation in EVERY row, eviction included — worst
    # absolute residual of started - (completed + evicted + in-table)
    "flows_conservation_resid": {"max_abs": 0.0},
    # FCT >= ideal FCT by construction, so slowdowns are >= 1; the
    # worst (smallest) p50 over every flow row pins it
    "flows_slowdown_p50_min": {"min_abs": 1.0},
    # the incast row must actually evict (table pressure exercised) and
    # every flow row must actually complete flows (percentiles are
    # measured, not vacuous)
    "flows_incast_evicted": {"min_abs": 1.0},
    "flows_completed_min": {"min_abs": 1.0},
    # gating keeps saving energy under flow-level traffic
    "flows_lcdc_savings_frac": {"min_abs": 0.05},
    # the whole grid is one vmapped batch: one compile, and the
    # validate=True pass (its own program) must come back clean
    "flows_traces": {"equal": True},
    "flows_validate_clean": {"equal": True},
}


def _grid_runs(site: FBSite):
    """(label, mode, SimParams) rows: flow workloads x {lcdc, base},
    two flow_mode=0 rows, and the incast/table-pressure row — all on
    one site so the grid is one ``make_batch`` compile."""
    spec = TRAFFIC_SPECS["fb_web"]
    rows = []
    # flow_mode=0: the rate-based engine, flow metrics must be inert
    for mode, gate in (("lcdc", True), ("base", False)):
        rows.append(("off", mode, SimParams(
            spec=spec, site=site, gating_enabled=gate, rate_scale=1.6)))
    for lvl, (dist, rate) in LEVELS.items():
        for mode, gate in (("lcdc", True), ("base", False)):
            rows.append((lvl, mode, SimParams(
                spec=spec, site=site, gating_enabled=gate, flow_mode=1,
                flow_size_dist=dist, flow_arrival_rate=rate)))
    # incast: 8-way bursts into an 8-slot table — forced eviction
    rows.append(("incast", "lcdc", SimParams(
        spec=spec, site=site, gating_enabled=True, flow_mode=1,
        flow_size_dist="websearch", flow_arrival_rate=0.3,
        incast_degree=8, flow_table_cap=8)))
    return rows


def _in_table(state, row: int, cap: int) -> float:
    """Flows still resident in row's usable table prefix at sweep end."""
    rem = np.asarray(state.ft_rem)[row]          # (R, FT)
    live = (rem > 0) & (np.arange(rem.shape[1])[None, :] < cap)
    return float(np.sum(live))


def bench_flows(args) -> dict:
    site = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                  csw_per_cluster=2, n_fc=2, csw_ring_links=4,
                  fc_ring_links=8) if args.smoke else FBSite()
    ticks = args.ticks or (2_000 if args.smoke else 20_000)
    chunk = max(1, ticks // 4)          # force a multi-chunk run
    rows = _grid_runs(site)
    batch = make_batch([(p, i) for i, (_, _, p) in enumerate(rows)])
    print(f"flow grid: {len(LEVELS)} workloads x {{lcdc, base}} "
          f"+ 2 off-rows + incast = {len(rows)} scenarios, "
          f"{ticks} ticks (chunk {chunk})")

    n0 = S.TRACE_COUNT
    t0 = time.time()
    res, state = run_sweep(batch, ticks, chunk_ticks=chunk,
                           return_state=True)
    t_grid = time.time() - t0
    traces = S.TRACE_COUNT - n0

    # exact flow-conservation census per row, eviction included
    resid = []
    for i, (_, _, p) in enumerate(rows):
        r = res[i]
        err = r["flows_started"] - (r["flows_completed"]
                                    + r["flows_evicted"]
                                    + _in_table(state, i, p.flow_table_cap))
        resid.append(abs(err))

    # the validate=True pass: same batch, in-program guards (a second
    # compile by design — the guard changes the chunk program)
    try:
        run_sweep(batch, min(ticks, 2 * chunk), chunk_ticks=chunk,
                  validate=True)
        validate_clean = 1
    except S.SweepValidationError as exc:
        print(f"validate=True pass FAILED: {exc}")
        validate_clean = 0

    by = {(lvl, mode): r for (lvl, mode, _), r in zip(rows, res)}
    zero_rows_max = max(
        abs(by["off", m][k])
        for m in ("lcdc", "base") for k in ZERO_FLOW_METRICS)
    flow_keys = [k for k in by if k[0] != "off"]
    slow_p50_min = min(by[k]["fct_slowdown_p50"] for k in flow_keys)
    completed_min = min(by[k]["flows_completed"] for k in flow_keys)

    print(f"\n{'level':10s} {'mode':5s} {'savings':>8s} {'started':>8s} "
          f"{'done':>7s} {'evict':>7s} {'sl_p50':>7s} {'sl_p99':>8s} "
          f"{'p99short':>9s} {'p99long':>10s}")
    frontier = []
    for lvl, mode, _ in rows:
        r = by[lvl, mode]
        print(f"{lvl:10s} {mode:5s} "
              f"{r['all_transceiver_savings_frac']:8.1%} "
              f"{r['flows_started']:8.0f} {r['flows_completed']:7.0f} "
              f"{r['flows_evicted']:7.0f} {r['fct_slowdown_p50']:7.2f} "
              f"{r['fct_slowdown_p99']:8.2f} "
              f"{r['fct_p99_us_short']:9.1f} {r['fct_p99_us_long']:10.1f}")
        frontier.append({
            "level": lvl, "mode": mode,
            "savings_frac": r["all_transceiver_savings_frac"],
            "flows_started": r["flows_started"],
            "flows_completed": r["flows_completed"],
            "flows_evicted": r["flows_evicted"],
            "fct_slowdown_p50": r["fct_slowdown_p50"],
            "fct_slowdown_p99": r["fct_slowdown_p99"],
            **{f"fct_p99_us_{c}": r[f"fct_p99_us_{c}"]
               for c in workloads.FLOW_CLASS_NAMES},
        })

    return {
        "ticks": ticks, "scenarios": len(rows),
        "t_grid_s": round(t_grid, 3),
        "flows_traces": traces,
        "flows_zero_rows_max_metric": zero_rows_max,
        "flows_conservation_resid": max(resid),
        "flows_slowdown_p50_min": slow_p50_min,
        "flows_incast_evicted": by["incast", "lcdc"]["flows_evicted"],
        "flows_completed_min": completed_min,
        "flows_lcdc_savings_frac": by["web-light", "lcdc"][
            "all_transceiver_savings_frac"],
        "flows_validate_clean": validate_clean,
        "frontier": frontier,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small site + short run, the CI flow canary")
    ap.add_argument("--check-baseline", action="store_true",
                    help="gate against the bench_flows baseline section")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless this run's values into baselines.json")
    args = ap.parse_args()

    results = {"smoke": args.smoke, "exec": S.execution_mode()}
    results.update(bench_flows(args))

    out = OUT.with_name("bench_flows_smoke.json") if args.smoke else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"written: {out}")

    mode = "smoke" if args.smoke else "full"
    sane = (results["flows_zero_rows_max_metric"] == 0.0
            and results["flows_conservation_resid"] == 0.0
            and results["flows_validate_clean"] == 1)
    if args.update_baseline:
        if not sane:
            raise SystemExit("refusing to bless baseline: this run "
                             "failed its own flow-model checks")
        bands = DEFAULT_BANDS
        prev = BG.load_section("bench_flows")
        if prev is not None and prev.get("mode") == mode:
            bands = {**DEFAULT_BANDS, **prev.get("bands", {})}
        missing = [k for k in bands if k not in results]
        if missing:
            raise SystemExit("refusing to bless baseline: banded "
                             f"metrics missing from this run: {missing}")
        BG.bless_section("bench_flows", mode,
                         {k: results[k] for k in bands}, bands)
        print(f"baseline blessed: {BG.BASELINE}")

    if args.check_baseline:
        baseline = BG.load_section("bench_flows")
        if baseline is None:
            raise SystemExit(f"no bench_flows baseline at {BG.BASELINE}; "
                             "bless one with --update-baseline and "
                             "commit it")
        if baseline.get("mode") != mode:
            raise SystemExit(
                f"baseline was blessed in {baseline.get('mode')!r} mode "
                f"but this run is {mode!r}; re-bless or match modes")
        print(f"\nbaseline gate ({BG.BASELINE.name}, mode={mode}):")
        fails = BG.check_bands(results, baseline)
        trajectory = BG.merge_trajectory("bench_flows", {
            "mode": mode, "gate": "failed" if fails else "passed",
            "exec": results["exec"],
            "checks": {k: results[k] for k in DEFAULT_BANDS},
            "frontier": results["frontier"],
            "timings_s": {"grid": results["t_grid_s"]},
        })
        print(f"trajectory record written: {trajectory}")
        if fails:
            raise SystemExit("baseline gate FAILED:\n  "
                             + "\n  ".join(fails))
        print("baseline gate passed")
    elif not sane:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
