"""Serial-vs-batched scenario-sweep benchmark (the sweep engine's
reason to exist): runs the full Fig 9/10 evaluation grid — every
traffic trace x {LC/DC, always-on} — once through serial ``run_sim``
calls (which re-trace and re-jit per scenario, the pre-sweep engine's
behaviour) and once through one batched ``run_sweep``, and reports
scenarios/sec, scenario-ticks/sec, the wall-clock speedup, and the
worst per-scenario metric divergence between the two paths.

  PYTHONPATH=src python -m benchmarks.bench_sweep            # full grid
  PYTHONPATH=src python -m benchmarks.bench_sweep --smoke    # <1 min canary

--smoke runs a 2-trace grid at 500 ticks: a fast perf canary for CI.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.simulator import (PARITY_KEYS, grid_runs, make_batch,
                                  run_sim, run_sweep)
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "bench_sweep.json"


def _rel_diff(a: float, b: float) -> float:
    return abs(a - b) / max(abs(a), abs(b), 1e-9)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, <1 min, for use as a perf canary")
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="max allowed serial-vs-batched relative diff")
    args = ap.parse_args()

    if args.smoke:
        traces, seeds, scales = ("fb_hadoop", "university"), (0,), (1.0,)
        ticks = args.ticks or 800
    else:
        # the full Fig 9/10 evaluation matrix: every trace x {LC/DC,
        # always-on} x seeds x utilization (rate) scales
        traces, seeds, scales = (tuple(TRAFFIC_SPECS), (0, 1, 2, 3),
                                 (0.6, 1.0))
        ticks = args.ticks or 1_000
    runs = grid_runs(traces=traces, seeds=seeds, rate_scales=scales)
    n = len(runs)
    print(f"grid: {len(traces)} traces x {{lcdc, base}} x {len(seeds)} "
          f"seeds x {len(scales)} utilizations = {n} scenarios, "
          f"{ticks} ticks each")

    t0 = time.time()
    batch = make_batch(runs)
    batched = run_sweep(batch, ticks)
    t_batched = time.time() - t0
    print(f"batched run_sweep : {t_batched:8.2f} s  "
          f"({n / t_batched:6.2f} scen/s, "
          f"{n * ticks / t_batched:9.0f} scen-ticks/s)")

    t0 = time.time()
    serial = [run_sim(p, ticks, s) for p, s in runs]
    t_serial = time.time() - t0
    print(f"serial run_sim x{n}: {t_serial:8.2f} s  "
          f"({n / t_serial:6.2f} scen/s, "
          f"{n * ticks / t_serial:9.0f} scen-ticks/s)")

    speedup = t_serial / t_batched
    worst_key, worst = None, 0.0
    for r_s, r_b in zip(serial, batched):
        for k in PARITY_KEYS:
            d = _rel_diff(r_s[k], r_b[k])
            if d > worst:
                worst_key, worst = f"{r_b['label']}:{k}", d
    ok = worst <= args.tol
    print(f"speedup: {speedup:.2f}x  "
          f"(target >= 3x on the full grid)")
    print(f"max serial-vs-batched rel diff: {worst:.2e} "
          f"[{worst_key}] {'OK' if ok else f'> tol {args.tol:g}'}")

    out = OUT.with_name("bench_sweep_smoke.json") if args.smoke else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "smoke": args.smoke, "ticks": ticks, "scenarios": n,
        "t_serial_s": round(t_serial, 3),
        "t_batched_s": round(t_batched, 3),
        "speedup": round(speedup, 3),
        "scen_ticks_per_s_batched": round(n * ticks / t_batched, 1),
        "scen_ticks_per_s_serial": round(n * ticks / t_serial, 1),
        "max_rel_diff": worst, "max_rel_diff_key": worst_key,
        "metrics_match": ok,
    }, indent=1))
    print(f"written: {out}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
