"""Sweep-engine benchmark + the CI perf/parity regression gate.

Three sections, all written to results/ and all gated by the committed
baseline (benchmarks/baselines.json) under ``--check-baseline``:

1. serial vs batched — the full Fig 9/10 evaluation grid (every traffic
   trace x {LC/DC, always-on}) once through serial ``run_sim`` calls
   (re-trace + re-jit per scenario, the pre-sweep engine's behaviour)
   and once through one batched ``run_sweep``; reports scenarios/sec,
   scenario-ticks/sec, the wall-clock speedup, and the worst
   per-scenario metric divergence between the two paths.

2. device fold vs host fold — the bimodal acceptance mix on a
   multi-chunk run: the device-resident Kahan fold (one host transfer
   for the whole run) against the legacy per-chunk host fold; reports
   wall clock for both, the host-transfer counts (the device path MUST
   do exactly 1), and the worst metric divergence (<= 1e-6: Kahan
   compensation holds the cross-chunk float32 error at O(eps)).

3. hull-bucketing planner — the acceptance mix: a bimodal 6-site batch
   (3 small + 3 large fabrics) through the async-pipelined
   ``run_sweep_planned(max_compiles=2)`` vs the single-hull
   ``make_multi_site_batch`` path, multi-chunk; reports the modeled
   padded-compute savings (>= 30% required), the trace counts (one
   compile per hull bucket), the host-transfer count (<= 1 per
   bucket), and the worst metric divergence between planned and
   single-hull results. The bucketing report is also written to
   results/bench_planner_report.json (a CI build artifact).

4. checkpoint overhead — the same mix with cadenced durability
   snapshots (core/checkpoint.py) vs plain: the overhead ratio (the
   deferred-by-one snapshot writes must throttle, not serialize, the
   async chunk pipeline), the ``1 + n_checkpoints`` host-transfer pin,
   and the BIT-exact (rel diff == 0.0) parity of both the checkpointed
   run and a ``resume_sweep`` from its last mid-run snapshot.

Under ``--check-baseline`` the run additionally merges a
machine-readable perf-trajectory record into the repo root's
``BENCH_<n>.json`` (n = the PR index derived from CHANGES.md; speedups,
parity, bucket + host-transfer stats, execution mode, gate outcome —
under the ``bench_sweep`` key, alongside other benchmarks' records) so
future PRs have a bench trajectory to compare against.

  PYTHONPATH=src python -m benchmarks.bench_sweep            # full grid
  PYTHONPATH=src python -m benchmarks.bench_sweep --smoke    # <1 min canary
  PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --check-baseline
  PYTHONPATH=src python -m benchmarks.bench_sweep --smoke --update-baseline

--check-baseline compares the run against this bench's SECTION of
benchmarks/baselines.json (shared machinery: baseline_gate.py) and
exits nonzero on any violated band: parity/savings/bucket-count gates
are machine-independent hard bounds, timing gates are generous ratios
to the blessed values (CI runners are noisy — the bands catch
order-of-magnitude regressions like a lost compile cache, not 10%
jitter). To bless a new baseline after an intentional perf change, run
with --update-baseline and commit the rewritten baselines.json (the
band definitions are preserved; only the blessed values move).
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from benchmarks import baseline_gate as BG
from repro.core import checkpoint as CK
from repro.core import simulator as S
from repro.core.simulator import (CheckpointSpec, SimParams, grid_runs,
                                  make_batch, make_multi_site_batch,
                                  resume_sweep, run_sim, run_sweep,
                                  run_sweep_planned, worst_parity)
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

RESULTS = Path(__file__).resolve().parents[1] / "results"
OUT = RESULTS / "bench_sweep.json"
PLAN_OUT = RESULTS / "bench_planner_report.json"

# the acceptance-criteria mix: 3 small + 3 large fabrics whose shared
# hull would waste most of the compute on padding the small ones
BIMODAL_SITES = (
    FBSite(n_clusters=2, racks_per_cluster=4, servers_per_rack=8,
           csw_per_cluster=2, n_fc=2, csw_ring_links=4, fc_ring_links=8),
    FBSite(n_clusters=2, racks_per_cluster=5, servers_per_rack=8,
           csw_per_cluster=2, n_fc=2, csw_ring_links=4, fc_ring_links=8),
    FBSite(n_clusters=2, racks_per_cluster=6, servers_per_rack=8,
           csw_per_cluster=2, n_fc=2, csw_ring_links=4, fc_ring_links=8),
    FBSite(),                                  # the Fig 2 4x32 default
    FBSite(racks_per_cluster=28),
    FBSite(racks_per_cluster=24),
)

#: default tolerance bands, used when blessing a baseline from scratch.
#: *_abs bands are machine-independent hard bounds; *_frac_of_baseline
#: bands are generous ratios to the blessed value (CI noise tolerant).
DEFAULT_BANDS = {
    "speedup": {"min_frac_of_baseline": 0.25},
    "scen_ticks_per_s_batched": {"min_frac_of_baseline": 0.20},
    "t_batched_s": {"max_frac_of_baseline": 5.0},
    "max_rel_diff": {"max_abs": 1e-3},
    # device-resident fold: parity vs the host-fold reference is a hard
    # 1e-6 bound (compensated f32), the transfer count a hard pin, the
    # wall-clock ratio a generous machine band
    "fold_max_rel_diff": {"max_abs": 1e-6},
    "fold_host_transfers_device": {"equal": True},
    "fold_speedup": {"min_frac_of_baseline": 0.20},
    "planner_savings_frac": {"min_abs": 0.30,
                             "min_frac_of_baseline": 0.90},
    "planner_max_rel_diff": {"max_abs": 1e-3},
    "planner_n_buckets": {"equal": True},
    "planner_traces": {"equal": True},
    # async bucket pipeline: exactly one fold fetch per bucket
    "host_transfers_per_bucket": {"max_abs": 1.0},
    # durability: checkpointing only OBSERVES a run (bit-exact parity,
    # rel diff == 0.0 — no epsilon), the snapshot fetches are pinned at
    # 1 + n_checkpoints, and the deferred-by-one writes keep the
    # overhead a bounded ratio of the plain run's wall clock
    "ckpt_overhead_ratio": {"max_abs": 2.0},
    "ckpt_max_rel_diff": {"max_abs": 0.0},
    "ckpt_host_transfers": {"equal": True},
    "ckpt_n_checkpoints": {"equal": True},
}


def bench_serial_vs_batched(args) -> dict:
    if args.smoke:
        traces, seeds, scales = ("fb_hadoop", "university"), (0,), (1.0,)
        ticks = args.ticks or 800
    else:
        # the full Fig 9/10 evaluation matrix: every trace x {LC/DC,
        # always-on} x seeds x utilization (rate) scales
        traces, seeds, scales = (tuple(TRAFFIC_SPECS), (0, 1, 2, 3),
                                 (0.6, 1.0))
        ticks = args.ticks or 1_000
    runs = grid_runs(traces=traces, seeds=seeds, rate_scales=scales)
    n = len(runs)
    print(f"grid: {len(traces)} traces x {{lcdc, base}} x {len(seeds)} "
          f"seeds x {len(scales)} utilizations = {n} scenarios, "
          f"{ticks} ticks each")

    t0 = time.time()
    batched = run_sweep(make_batch(runs), ticks)
    t_batched = time.time() - t0
    print(f"batched run_sweep : {t_batched:8.2f} s  "
          f"({n / t_batched:6.2f} scen/s, "
          f"{n * ticks / t_batched:9.0f} scen-ticks/s)")

    t0 = time.time()
    serial = [run_sim(p, ticks, s) for p, s in runs]
    t_serial = time.time() - t0
    print(f"serial run_sim x{n}: {t_serial:8.2f} s  "
          f"({n / t_serial:6.2f} scen/s, "
          f"{n * ticks / t_serial:9.0f} scen-ticks/s)")

    speedup = t_serial / t_batched
    worst, worst_key = worst_parity(serial, batched)
    ok = worst <= args.tol
    print(f"speedup: {speedup:.2f}x  "
          f"(target >= 3x on the full grid)")
    print(f"max serial-vs-batched rel diff: {worst:.2e} "
          f"[{worst_key}] {'OK' if ok else f'> tol {args.tol:g}'}")
    return {
        "ticks": ticks, "scenarios": n,
        "t_serial_s": round(t_serial, 3),
        "t_batched_s": round(t_batched, 3),
        "speedup": round(speedup, 3),
        "scen_ticks_per_s_batched": round(n * ticks / t_batched, 1),
        "scen_ticks_per_s_serial": round(n * ticks / t_serial, 1),
        "max_rel_diff": worst, "max_rel_diff_key": worst_key,
        "metrics_match": ok,
    }


def _bimodal_runs():
    spec = TRAFFIC_SPECS["fb_hadoop"]
    return [(SimParams(spec=spec, site=site), i)
            for i, site in enumerate(BIMODAL_SITES)]


def bench_fold(args) -> dict:
    """Device-resident fold vs the legacy per-chunk host fold on a
    multi-chunk run of the acceptance mix (single hull, so the two
    paths time the exact same simulation work)."""
    # >= 15 chunks: the paths differ by a fixed per-chunk sync cost, so
    # enough boundaries are needed for the delta to clear timing noise
    # on a small CI machine (measured stable at this scale)
    ticks, chunk = (1_500, 100) if args.smoke else (8_000, 400)
    if args.ticks:
        ticks, chunk = args.ticks, max(1, args.ticks // 15)
    n_chunks = -(-ticks // chunk)
    batch = make_multi_site_batch(_bimodal_runs())
    print(f"\nfold: bimodal mix as one hull, {ticks} ticks in "
          f"{n_chunks} chunks of {chunk}")

    # warm both fold programs (same (hull, B, chunk) key as the timed
    # runs, so the timed section measures execution, not compile)
    run_sweep(batch, 2 * chunk, chunk_ticks=chunk)
    run_sweep(batch, 2 * chunk, chunk_ticks=chunk, fold="host")

    # best-of-4 reps, order swapped each rep: the first run after a
    # warmup carries allocator/cache noise and a fixed A-then-B order
    # systematically favors one path — both misread single-shot timing
    t_host = t_dev = float("inf")
    for rep in range(4):
        for which in (("host", "device") if rep % 2 == 0
                      else ("device", "host")):
            h0 = S.HOST_TRANSFER_COUNT
            t0 = time.time()
            if which == "host":
                host_res = run_sweep(batch, ticks, chunk_ticks=chunk,
                                     fold="host")
                t_host = min(t_host, time.time() - t0)
                transfers_host = S.HOST_TRANSFER_COUNT - h0
            else:
                dev_res = run_sweep(batch, ticks, chunk_ticks=chunk)
                t_dev = min(t_dev, time.time() - t0)
                transfers_dev = S.HOST_TRANSFER_COUNT - h0

    worst, worst_key = worst_parity(host_res, dev_res)
    ok = worst <= 1e-6 and transfers_dev == 1
    print(f"host fold   : {t_host:7.2f} s, {transfers_host} host "
          f"transfers ({n_chunks} chunks)")
    print(f"device fold : {t_dev:7.2f} s, {transfers_dev} host "
          f"transfer(s) (require exactly 1)")
    print(f"max device-vs-host-fold rel diff: {worst:.2e} [{worst_key}] "
          f"{'OK' if ok else '> 1e-6 or extra transfers'}")
    return {
        "fold_ticks": ticks, "fold_chunks": n_chunks,
        "t_fold_host_s": round(t_host, 3),
        "t_fold_device_s": round(t_dev, 3),
        "fold_speedup": round(t_host / t_dev, 3),
        "fold_host_transfers_host": transfers_host,
        "fold_host_transfers_device": transfers_dev,
        "fold_max_rel_diff": worst, "fold_max_rel_diff_key": worst_key,
        "fold_metrics_match": ok,
    }


def bench_planner(args) -> dict:
    """Planned (async-pipelined) vs single-hull on the bimodal
    acceptance mix, multi-chunk."""
    ticks = (args.ticks or 500) if args.smoke else (args.ticks or 4_000)
    chunk = max(1, ticks // 5)      # force a multi-chunk run
    runs = _bimodal_runs()
    print(f"\nplanner: bimodal mix, {len(runs)} sites "
          f"(3 small + 3 large), {ticks} ticks (chunk {chunk}), "
          f"max_compiles=2")

    # warm BOTH paths at the timed (hull, B, chunk) keys so the timed
    # section compares execution, not compile (the planned path would
    # otherwise pay its bucket compiles inside the timed region and the
    # trajectory record would report a bogus planner slowdown). The
    # one-compile-per-bucket contract is pinned on the COLD warmup run,
    # where the traces actually happen.
    run_sweep(make_multi_site_batch(runs), 2 * chunk, chunk_ticks=chunk)
    n0 = S.TRACE_COUNT
    run_sweep_planned(runs, 2 * chunk, max_compiles=2, chunk_ticks=chunk)
    traces_planned = S.TRACE_COUNT - n0

    n0 = S.TRACE_COUNT
    t0 = time.time()
    single = run_sweep(make_multi_site_batch(runs), ticks,
                       chunk_ticks=chunk)
    t_single = time.time() - t0
    traces_single = S.TRACE_COUNT - n0

    h0 = S.HOST_TRANSFER_COUNT
    t0 = time.time()
    planned, plan = run_sweep_planned(runs, ticks, max_compiles=2,
                                      chunk_ticks=chunk, return_plan=True)
    t_planned = time.time() - t0
    transfers_planned = S.HOST_TRANSFER_COUNT - h0

    worst, worst_key = worst_parity(single, planned)
    transfers_per_bucket = transfers_planned / max(plan["n_buckets"], 1)
    ok = worst <= args.tol and transfers_per_bucket <= 1.0
    savings = plan["savings_vs_single_hull_frac"]
    print(f"single hull : {t_single:7.2f} s, {traces_single} trace(s), "
          f"padded cost {plan['single_hull_cost']:.0f}")
    print(f"planned K=2 : {t_planned:7.2f} s, {traces_planned} trace(s), "
          f"{transfers_planned} host transfer(s) for "
          f"{plan['n_buckets']} buckets (require <= 1 per bucket), "
          f"dispatch order {plan['dispatch_order']}, "
          f"padded cost {plan['padded_cost']:.0f}")
    for b in plan["buckets"]:
        print(f"  hull {b['hull']:22s} x{b['n_scenarios']}  "
              f"waste {b['waste_frac']:6.1%}")
    print(f"padded-compute savings: {savings:.1%} (require >= 30%)")
    print(f"max planned-vs-single-hull rel diff: {worst:.2e} "
          f"[{worst_key}] {'OK' if ok else f'> tol {args.tol:g}'}")

    PLAN_OUT.parent.mkdir(parents=True, exist_ok=True)
    PLAN_OUT.write_text(json.dumps({
        "smoke": args.smoke, "ticks": ticks,
        "t_single_hull_s": round(t_single, 3),
        "t_planned_s": round(t_planned, 3),
        "max_rel_diff": worst, "max_rel_diff_key": worst_key,
        "plan": plan,
    }, indent=1))
    print(f"written: {PLAN_OUT}")
    return {
        "planner_ticks": ticks,
        "planner_chunk_ticks": chunk,
        "planner_savings_frac": savings,
        "planner_waste_frac": plan["waste_frac"],
        "planner_n_buckets": plan["n_buckets"],
        "planner_traces": traces_planned,
        "planner_host_transfers": transfers_planned,
        "host_transfers_per_bucket": transfers_per_bucket,
        "planner_dispatch_order": plan["dispatch_order"],
        "planner_max_rel_diff": worst,
        "planner_max_rel_diff_key": worst_key,
        "planner_metrics_match": ok,
        "t_single_hull_s": round(t_single, 3),
        "t_planned_s": round(t_planned, 3),
        "planner_fingerprint": plan["fingerprint"],
    }


def bench_checkpoint(args) -> dict:
    """Checkpointed vs plain device-fold run on the bimodal mix
    (multi-chunk): the cadenced snapshots (core/checkpoint.py) must
    only OBSERVE the run — bit-exact metric parity (rel diff == 0.0,
    no epsilon) for both the checkpointed run and a resume_sweep from
    its last mid-run snapshot — while the deferred-by-one writes keep
    the device pipeline busy (overhead gated as a ratio of the plain
    run) and the fetch count is pinned at exactly 1 + n_checkpoints."""
    ticks, chunk = (1_500, 100) if args.smoke else (8_000, 400)
    if args.ticks:
        ticks, chunk = args.ticks, max(1, args.ticks // 15)
    n_chunks = -(-ticks // chunk)
    every = max(1, n_chunks // 4)
    # snapshot boundaries: every cadence'th chunk boundary, final
    # boundary excluded (a finished run needs no checkpoint)
    n_ckpt = sum(1 for ci in range(1, n_chunks) if ci % every == 0)
    batch = make_multi_site_batch(_bimodal_runs())
    print(f"\ncheckpoint: bimodal mix as one hull, {ticks} ticks in "
          f"{n_chunks} chunks of {chunk}, snapshot every {every} "
          f"chunk(s) -> {n_ckpt} checkpoint(s)")

    # warm the shared fold program (same (hull, B, chunk) key)
    run_sweep(batch, 2 * chunk, chunk_ticks=chunk)

    with tempfile.TemporaryDirectory() as td:
        spec = CheckpointSpec(directory=Path(td), every_chunks=every,
                              tag="bench", keep=max(1, n_ckpt))
        # best-of-4 reps, order swapped each rep (same rationale as
        # bench_fold: allocator noise + order bias)
        t_plain = t_ckpt = float("inf")
        for rep in range(4):
            for which in (("plain", "ckpt") if rep % 2 == 0
                          else ("ckpt", "plain")):
                h0 = S.HOST_TRANSFER_COUNT
                t0 = time.time()
                if which == "plain":
                    plain_res = run_sweep(batch, ticks, chunk_ticks=chunk)
                    t_plain = min(t_plain, time.time() - t0)
                else:
                    ckpt_res = run_sweep(batch, ticks, chunk_ticks=chunk,
                                         checkpoint=spec)
                    t_ckpt = min(t_ckpt, time.time() - t0)
                    transfers_ckpt = S.HOST_TRANSFER_COUNT - h0

        # resume from the newest mid-run snapshot: must land on the
        # exact same metrics as the uninterrupted runs
        latest = CK.latest_checkpoint(Path(td), "bench")
        resumed = resume_sweep(latest)

    w_ckpt, k_ckpt = worst_parity(plain_res, ckpt_res)
    w_res, k_res = worst_parity(plain_res, resumed)
    worst, worst_key = max((w_ckpt, k_ckpt), (w_res, k_res))
    overhead = t_ckpt / t_plain
    ok = (worst == 0.0 and transfers_ckpt == 1 + n_ckpt)
    print(f"plain run    : {t_plain:7.2f} s")
    print(f"checkpointed : {t_ckpt:7.2f} s  ({overhead:.2f}x plain), "
          f"{transfers_ckpt} host transfer(s) "
          f"(require exactly 1 + {n_ckpt})")
    print(f"max ckpt/resume-vs-plain rel diff: {worst:.2e} [{worst_key}] "
          f"{'OK' if ok else '!= 0.0 or transfer pin broken'}")
    return {
        "ckpt_ticks": ticks, "ckpt_chunks": n_chunks,
        "ckpt_every_chunks": every,
        "ckpt_n_checkpoints": n_ckpt,
        "t_ckpt_plain_s": round(t_plain, 3),
        "t_ckpt_checkpointed_s": round(t_ckpt, 3),
        "ckpt_overhead_ratio": round(overhead, 3),
        "ckpt_host_transfers": transfers_ckpt,
        "ckpt_max_rel_diff": worst, "ckpt_max_rel_diff_key": worst_key,
        "ckpt_metrics_match": ok,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, <1 min, for use as a perf canary")
    ap.add_argument("--tol", type=float, default=1e-3,
                    help="max allowed cross-path relative metric diff")
    ap.add_argument("--check-baseline", action="store_true",
                    help="gate this run against benchmarks/baselines.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="bless this run's values into baselines.json")
    args = ap.parse_args()

    results = {"smoke": args.smoke, "exec": S.execution_mode()}
    results.update(bench_serial_vs_batched(args))
    results.update(bench_fold(args))
    results.update(bench_planner(args))
    results.update(bench_checkpoint(args))

    out = OUT.with_name("bench_sweep_smoke.json") if args.smoke else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1))
    print(f"written: {out}")

    mode = "smoke" if args.smoke else "full"
    sane = (results["metrics_match"] and results["planner_metrics_match"]
            and results["fold_metrics_match"]
            and results["ckpt_metrics_match"])
    if args.update_baseline:
        # never bless a run that failed its own parity checks — a
        # broken run must not become the new reference
        if not sane:
            raise SystemExit("refusing to bless baseline: this run "
                             "failed its parity checks (max_rel_diff / "
                             "planner_max_rel_diff above --tol)")
        bands = DEFAULT_BANDS
        prev = BG.load_section("bench_sweep")
        if prev is not None and prev.get("mode") == mode:
            # keep hand-tuned bands for metrics that already had
            # one, but pick up newly introduced default bands too
            # (a re-bless must not silently drop a new gate)
            bands = {**DEFAULT_BANDS, **prev.get("bands", {})}
        missing = [k for k in bands if k not in results]
        if missing:
            raise SystemExit("refusing to bless baseline: banded "
                             f"metrics missing from this run: {missing}")
        BG.bless_section("bench_sweep", mode,
                         {k: results[k] for k in bands}, bands)
        print(f"baseline blessed: {BG.BASELINE}")

    if args.check_baseline:
        baseline = BG.load_section("bench_sweep")
        if baseline is None:
            raise SystemExit(f"no bench_sweep baseline at {BG.BASELINE}; "
                             "bless one with --update-baseline and "
                             "commit it")
        if baseline.get("mode") != mode:
            raise SystemExit(
                f"baseline was blessed in {baseline.get('mode')!r} mode "
                f"but this run is {mode!r}; re-bless or match modes")
        print(f"\nbaseline gate ({BG.BASELINE.name}, mode={mode}):")
        fails = BG.check_bands(results, baseline)
        # the perf-trajectory record: written even on gate failure (the
        # trajectory should record regressions, not hide them)
        record = {
            "mode": mode,
            "gate": "failed" if fails else "passed",
            "exec": results["exec"],
            "speedups": {
                "serial_vs_batched": results["speedup"],
                "fold_host_vs_device": results["fold_speedup"],
                "scen_ticks_per_s_batched":
                    results["scen_ticks_per_s_batched"],
            },
            "parity": {
                "serial_vs_batched": results["max_rel_diff"],
                "fold_device_vs_host": results["fold_max_rel_diff"],
                "planned_vs_single_hull": results["planner_max_rel_diff"],
            },
            "buckets": {
                "n_buckets": results["planner_n_buckets"],
                "traces": results["planner_traces"],
                "host_transfers_per_bucket":
                    results["host_transfers_per_bucket"],
                "dispatch_order": results["planner_dispatch_order"],
                "savings_frac": results["planner_savings_frac"],
                "waste_frac": results["planner_waste_frac"],
            },
            "durability": {
                "n_checkpoints": results["ckpt_n_checkpoints"],
                "host_transfers": results["ckpt_host_transfers"],
                "overhead_ratio": results["ckpt_overhead_ratio"],
                "max_rel_diff": results["ckpt_max_rel_diff"],
            },
            "timings_s": {
                "batched": results["t_batched_s"],
                "serial": results["t_serial_s"],
                "fold_device": results["t_fold_device_s"],
                "fold_host": results["t_fold_host_s"],
                "planned": results["t_planned_s"],
                "single_hull": results["t_single_hull_s"],
                "ckpt_plain": results["t_ckpt_plain_s"],
                "ckpt_checkpointed": results["t_ckpt_checkpointed_s"],
            },
        }
        trajectory = BG.merge_trajectory("bench_sweep", record)
        print(f"trajectory record written: {trajectory}")
        if fails:
            raise SystemExit("baseline gate FAILED:\n  "
                             + "\n  ".join(fails))
        print("baseline gate passed")
    elif not sane:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
