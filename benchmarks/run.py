"""Benchmark harness entry point: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``us_per_call`` is the wall time
of producing that figure's numbers (simulation/analysis cost); ``derived``
carries the figure's headline metrics next to the paper's claims.

  PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations


def main() -> None:
    rows = []

    def report(name, seconds, derived):
        rows.append((name, seconds * 1e6, derived))

    from benchmarks.bench_figures import ALL
    for bench in ALL:
        try:
            bench(report)
        except Exception as e:  # noqa: BLE001 - a bench must not kill the run
            rows.append((bench.__name__, 0.0, f"ERROR: {e}"))

    # roofline summary (full table via `python -m benchmarks.roofline`)
    try:
        from benchmarks.roofline import full_table
        import numpy as np
        t = [r for r in full_table() if "skipped" not in r]
        if t:
            dom = {}
            for r in t:
                dom[r["dominant"]] = dom.get(r["dominant"], 0) + 1
            rows.append(("roofline_summary", 0.0,
                         f"{len(t)} cells; dominant terms: {dom}; "
                         f"median MODEL/HLO="
                         f"{np.median([r['model_to_hlo_ratio'] for r in t]):.3f}"))
    except Exception as e:  # noqa: BLE001
        rows.append(("roofline_summary", 0.0, f"ERROR: {e}"))

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        d = str(derived).replace(",", ";")
        print(f"{name},{us:.1f},{d}")


if __name__ == "__main__":
    main()
