"""Roofline analysis (deliverable g): three terms per (arch x shape) cell
from the dry-run artifacts, per EXPERIMENTS.md SSRoofline.

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s)
    memory term     = HLO_bytes / (chips x 819 GB/s)
    collective term = collective_link_bytes / (chips x 50 GB/s/link)

HLO_FLOPs/bytes come from the dry-run ACCOUNTING pass (unrolled L1/L2
delta -> exact per-layer totals; scan-over-layers hides trip counts from
cost_analysis). Two corrections applied and reported:

  * post-SPMD HLO quantities are per-device, so `chips` is already
    divided out;
  * rwkv/mamba time recurrences stay inside while loops even in the
    accounting pass; their FLOPs are added analytically
    (10*B*T*H*dh^2 wkv / 12*B*T*d_in*N mamba per layer, fwd; x4 for
    train with full remat).

MODEL_FLOPS = 6*N(_active)*D (train) or 2*N*D (prefill/decode); the
MODEL/HLO ratio flags remat/redundancy waste. "MFU bound" =
MODEL_FLOPS-ideal time / max(term): the best MFU any schedule could
reach given the compiled traffic, assuming perfect overlap.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ARCH_IDS, SHAPES, cells_for, get_config
from repro.core import constants as C
from repro.models.model import _stack_plan

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"
OUT = Path(__file__).resolve().parents[1] / "results" / "roofline.json"

PEAK = C.TPU_PEAK_BF16_FLOPS
HBM = C.TPU_HBM_BW
LINK = C.TPU_ICI_LINK_BW


def recurrence_flops_per_device(cfg, shape, n_chips=256) -> float:
    """Analytic FLOPs of scan-hidden recurrences (global / chips)."""
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        T = 1
    mult = 4.0 if shape.kind == "train" else 1.0   # bwd + remat recompute
    total = 0.0
    if cfg.family == "ssm":                        # rwkv wkv
        H = cfg.d_model // cfg.rwkv_head_dim
        dh = cfg.rwkv_head_dim
        total += 10.0 * B * T * H * dh * dh * cfg.n_layers
    if cfg.mamba is not None:                      # jamba mamba layers
        d_in = cfg.mamba.expand * cfg.d_model
        n_mamba = sum(1 for i in range(cfg.n_layers)
                      if cfg.layer_kind(i) == "mamba")
        total += 12.0 * B * T * d_in * cfg.mamba.d_state * n_mamba
    return mult * total / n_chips


def model_flops(cfg, shape) -> float:
    """Spec MODEL_FLOPS: 6*N(_active)*D train, 2*N(_active)*D inference."""
    D = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    N = cfg.n_active_params()
    return (6.0 if shape.kind == "train" else 2.0) * N * D


def load_cell(arch: str, shape_name: str, mesh="single") -> dict | None:
    f = RESULTS / f"{arch}__{shape_name}__{mesh}.json"
    if not f.exists():
        return None
    rec = json.loads(f.read_text())
    return rec if rec.get("ok") else None


def cell_roofline(arch: str, shape_name: str, *, n_chips=256,
                  mesh="single") -> dict | None:
    rec = load_cell(arch, shape_name, mesh)
    if rec is None:
        return None
    cfg = get_config(arch)
    shape = SHAPES[shape_name]

    acct = rec.get("acct")
    if acct:
        flops = acct["total_flops"]
        bytes_ = acct["total_bytes"]
        coll = acct["total_coll_link_bytes"]
        src = "acct(L2-L1)"
    else:
        flops = rec["cost"]["flops"]
        bytes_ = rec["cost"]["bytes_accessed"]
        coll = rec["collective_link_bytes"]
        src = "scan(cost_analysis, per-layer-undercounted)"

    rec_fl = recurrence_flops_per_device(cfg, shape, n_chips)
    flops += rec_fl

    t_comp = flops / PEAK
    t_mem = bytes_ / HBM
    t_coll = coll / LINK
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape)
    t_ideal = mf / n_chips / PEAK
    bound = max(t_comp, t_mem, t_coll)
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh,
        "flops_per_dev": flops, "bytes_per_dev": bytes_,
        "coll_link_bytes_per_dev": coll,
        "recurrence_flops_added": rec_fl,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf,
        "model_to_hlo_ratio": mf / n_chips / max(flops, 1e-9),
        "mfu_bound": t_ideal / max(bound, 1e-12),
        "temp_gib_per_dev": rec["memory"]["temp_size_in_bytes"] / 2 ** 30,
        "args_gib_per_dev": rec["memory"]["argument_size_in_bytes"] / 2 ** 30,
        "source": src,
    }


def full_table() -> list[dict]:
    rows = []
    for arch in ARCH_IDS:
        for cell in cells_for(get_config(arch)):
            if not cell.run:
                rows.append({"arch": arch, "shape": cell.shape.name,
                             "skipped": cell.skip_reason})
                continue
            r = cell_roofline(arch, cell.shape.name)
            rows.append(r or {"arch": arch, "shape": cell.shape.name,
                              "skipped": "dry-run record missing/failed"})
    return rows


def render(rows) -> str:
    hdr = (f"{'arch':22s} {'shape':12s} {'t_comp':>9s} {'t_mem':>9s} "
           f"{'t_coll':>9s} {'dom':>6s} {'MODEL/HLO':>9s} {'MFUbnd':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "skipped" in r:
            lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                         f"-- skipped: {r['skipped'][:48]}")
            continue
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} "
            f"{r['t_compute_s']:9.4f} {r['t_memory_s']:9.4f} "
            f"{r['t_collective_s']:9.4f} {r['dominant'][:6]:>6s} "
            f"{r['model_to_hlo_ratio']:9.3f} {r['mfu_bound']:7.3f}")
    return "\n".join(lines)


def main():
    rows = full_table()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_text(json.dumps(rows, indent=1))
    print(render(rows))
    print(f"\nwritten: {OUT}")


if __name__ == "__main__":
    main()
