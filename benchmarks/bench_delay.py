"""Fig-10-style savings-vs-delay frontier on the in-scan delay
distributions: a utilization x watermark grid of LC/DC scenarios (plus
one always-on baseline per utilization) runs as ONE batched sweep — a
single compile — and reports, per cell, the switch-tier energy savings
against the p50/p95/p99 packet-delay penalty and its attribution
(queueing vs STAGE_UP_DELAY wake stalls vs ring detours).

The paper's headline is "60% power saved at the cost of 6% higher
delay"; this bench reproduces that tradeoff as a frontier — more
aggressive watermarks / lower utilization buy more savings at a larger
delay-tail penalty — and checks the frontier is monotone-ish (delay
penalty rising with savings when sorted).

  PYTHONPATH=src python -m benchmarks.bench_delay            # full grid
  PYTHONPATH=src python -m benchmarks.bench_delay --smoke    # CI canary
  PYTHONPATH=src python -m benchmarks.bench_delay --check    # + assert

--smoke runs a 2x2 grid at 800 ticks (<1 min); --check exits nonzero if
the sweep re-traces or the frontier is grossly non-monotone.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core import simulator as S
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "bench_delay.json"

# (hi, lo) watermark pairs, most aggressive (latest stage-up, most
# savings) first; the default Sec V pair is in the middle
WATERMARKS = ((0.9, 0.4), (0.75, 0.22), (0.6, 0.15), (0.45, 0.1))


def frontier_monotone_frac(rows, key="penalty_p99"):
    """Fraction of adjacent pairs (sorted by savings) whose delay
    penalty does not decrease — 1.0 is a perfectly monotone frontier."""
    srt = sorted(rows, key=lambda r: r["switch_energy_savings_frac"])
    if len(srt) < 2:
        return 1.0
    ok = sum(b[key] >= a[key] - 0.02 for a, b in zip(srt, srt[1:]))
    return ok / (len(srt) - 1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=None)
    ap.add_argument("--trace", default="fb_hadoop",
                    choices=sorted(TRAFFIC_SPECS))
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid, <1 min, for use as a CI canary")
    ap.add_argument("--check", action="store_true",
                    help="assert one compile + monotone-ish frontier")
    args = ap.parse_args()

    if args.smoke:
        utils, wms = (0.6, 1.4), WATERMARKS[1:3]
        ticks = args.ticks or 800
    else:
        utils, wms = (0.4, 0.8, 1.2, 1.6), WATERMARKS
        ticks = args.ticks or 6_000

    spec = TRAFFIC_SPECS[args.trace]
    runs, cells = [], []
    for rs in utils:
        # one always-on baseline per utilization (watermarks are inert
        # with gating off; no need to repeat it per pair)
        runs.append((S.SimParams(spec=spec, gating_enabled=False,
                                 rate_scale=rs), 0))
        cells.append(("base", rs, None))
        for hi, lo in wms:
            runs.append((S.SimParams(spec=spec, gating_enabled=True,
                                     rate_scale=rs, hi=hi, lo=lo), 0))
            cells.append(("lcdc", rs, (hi, lo)))
    batch = S.make_batch(runs)
    print(f"{len(utils)} utilizations x {len(wms)} watermark pairs "
          f"(+{len(utils)} baselines) = {len(runs)} scenarios, "
          f"trace={args.trace}, {ticks} ticks, ONE compile")

    n0 = S.TRACE_COUNT
    t0 = time.time()
    res = S.run_sweep(batch, ticks)
    wall = time.time() - t0
    traces = S.TRACE_COUNT - n0
    print(f"sweep: {wall:.2f} s, step traces: {traces} (contract: 1)")

    base_by_util = {c[1]: r for c, r in zip(cells, res) if c[0] == "base"}
    rows = []
    print(f"\n{'util':>5} {'hi/lo':>9} {'savings':>8} {'p50':>7} "
          f"{'p99':>7} {'pen50':>7} {'pen99':>7} {'stall_us':>8} "
          f"{'queue_us':>8}")
    for cell, r in zip(cells, res):
        kind, rs, wm = cell
        if kind != "lcdc":
            continue
        b = base_by_util[rs]
        row = {
            "util": rs, "hi": wm[0], "lo": wm[1], "label": r["label"],
            "switch_energy_savings_frac": r["switch_energy_savings_frac"],
            "delay_p50_us": r["delay_p50_us"],
            "delay_p95_us": r["delay_p95_us"],
            "delay_p99_us": r["delay_p99_us"],
            "base_p50_us": b["delay_p50_us"],
            "base_p99_us": b["delay_p99_us"],
            "penalty_p50": r["delay_p50_us"] / b["delay_p50_us"] - 1.0,
            "penalty_p99": r["delay_p99_us"] / b["delay_p99_us"] - 1.0,
            "penalty_mean": (r["delay_mean_sampled_us"]
                             / b["delay_mean_sampled_us"] - 1.0),
            "delay_queue_us": r["delay_queue_us"],
            "delay_wake_stall_us": r["delay_wake_stall_us"],
            "delay_ring_us": r["delay_ring_us"],
            "wake_stall_frac": r["wake_stall_frac"],
        }
        rows.append(row)
        print(f"{rs:5.2f} {wm[0]:.2f}/{wm[1]:.2f} "
              f"{row['switch_energy_savings_frac']:8.3f} "
              f"{row['delay_p50_us']:7.2f} {row['delay_p99_us']:7.2f} "
              f"{row['penalty_p50']*100:+6.1f}% "
              f"{row['penalty_p99']*100:+6.1f}% "
              f"{row['delay_wake_stall_us']:8.4f} "
              f"{row['delay_queue_us']:8.3f}")

    mono = frontier_monotone_frac(rows)
    stall_ok = all(base_by_util[rs]["delay_wake_stall_us"] == 0.0
                   for rs in utils)
    print(f"\nfrontier monotone-ish (p99 penalty vs savings): "
          f"{mono:.0%} of adjacent pairs")
    print(f"baseline wake-stall attribution exactly 0: {stall_ok}")

    out = OUT.with_name("bench_delay_smoke.json") if args.smoke else OUT
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps({
        "smoke": args.smoke, "trace": args.trace, "ticks": ticks,
        "scenarios": len(runs), "step_traces": traces,
        "wall_s": round(wall, 3), "frontier_monotone_frac": mono,
        "baseline_stall_zero": stall_ok, "rows": rows,
    }, indent=1))
    print(f"written: {out}")

    if args.check and (traces != 1 or not stall_ok or mono < 0.5):
        raise SystemExit(
            f"frontier check failed: traces={traces} (want 1), "
            f"stall_zero={stall_ok}, monotone_frac={mono:.2f} (want>=0.5)")


if __name__ == "__main__":
    main()
