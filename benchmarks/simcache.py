"""Shared simulation runner for the Fig 8/9/10 benchmarks: runs every
trace once (LC/DC + always-on baseline) and caches to results/."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core.simulator import SimParams, run_sim
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "sim_results.json"
TICKS = 100_000


def get_results(ticks: int = TICKS, force: bool = False) -> dict:
    data = {"ticks": ticks, "traces": {}}
    if OUT.exists() and not force:
        prev = json.loads(OUT.read_text())
        if prev.get("ticks") == ticks:
            data = prev
    OUT.parent.mkdir(parents=True, exist_ok=True)
    for name, spec in TRAFFIC_SPECS.items():
        if name in data["traces"]:
            continue
        t0 = time.time()
        lc = run_sim(SimParams(spec=spec, gating_enabled=True), ticks, seed=0)
        base = run_sim(SimParams(spec=spec, gating_enabled=False), ticks,
                       seed=0)
        data["traces"][name] = {
            "lcdc": lc, "baseline": base,
            "wall_s": round(time.time() - t0, 1),
        }
        OUT.write_text(json.dumps(data, indent=1))   # incremental save
    return data
