"""Shared simulation runner for the Fig 8/9/10 benchmarks: runs every
trace (LC/DC + always-on baseline) as ONE batched sweep — a single
compile + vmapped scan over the whole grid — and caches to results/.

The cache key is not just ``ticks``: it carries the simulator's
``SIM_SCHEMA_VERSION`` and the full site fingerprint, so results cached
before a simulator semantics change (or for a different FBSite) are
invalidated instead of silently served stale.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.core.simulator import (SIM_SCHEMA_VERSION, SimParams,
                                  _site_tag, make_batch, run_sweep)
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "sim_results.json"
TICKS = 100_000


def _cache_meta(site: FBSite, ticks: int) -> dict:
    return {"sim_schema": SIM_SCHEMA_VERSION, "ticks": ticks,
            "site": dataclasses.asdict(site)}


def _cache_path(site: FBSite, ticks: int) -> Path:
    # non-default configurations get their own file so they coexist
    # with (rather than clobber) the default cache; the tag covers
    # EVERY FBSite field so distinct sites never share a file
    if site == FBSite() and ticks == TICKS:
        return OUT
    tag = (f"{_site_tag(site)}s{site.servers_per_rack}"
           f"r{site.csw_ring_links}-{site.fc_ring_links}_{ticks}")
    return OUT.with_name(f"sim_results_{tag}.json")


def get_results(ticks: int = TICKS, force: bool = False,
                site: FBSite = FBSite()) -> dict:
    meta = _cache_meta(site, ticks)
    out = _cache_path(site, ticks)
    data = {"meta": meta, "ticks": ticks, "traces": {}}
    if out.exists() and not force:
        prev = json.loads(out.read_text())
        # pre-schema caches have no "meta" at all -> invalidated too
        if prev.get("meta") == meta:
            data = prev
    missing = [n for n in TRAFFIC_SPECS if n not in data["traces"]]
    if not missing:
        return data
    out.parent.mkdir(parents=True, exist_ok=True)
    # one B=2 sweep per missing trace: every call after the first reuses
    # the same cached compile (identical batch shape), and the per-trace
    # incremental save keeps an interrupted 100k-tick run resumable
    for name in missing:
        spec = TRAFFIC_SPECS[name]
        t0 = time.time()
        lc, base = run_sweep(make_batch(
            [(SimParams(spec=spec, site=site, gating_enabled=True), 0),
             (SimParams(spec=spec, site=site, gating_enabled=False), 0)]),
            ticks)
        data["traces"][name] = {
            "lcdc": lc, "baseline": base,
            "wall_s": round(time.time() - t0, 1),
        }
        out.write_text(json.dumps(data, indent=1))   # incremental save
    return data
