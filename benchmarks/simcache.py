"""Shared simulation runner for the Fig 8/9/10 benchmarks: runs every
trace (LC/DC + always-on baseline) through the hull-bucketing sweep
planner (core/planner.py; one site -> the K=1 degenerate bucket) and
caches to results/.

The cache key is not just ``ticks``: it carries the simulator's
``SIM_SCHEMA_VERSION``, the full site fingerprint, the planner's
bucketing fingerprint (bucket assignment + hulls), AND the execution
mode (fold path + fold precision + device layout,
``simulator.execution_mode()``), so results cached before a simulator
semantics change, for a different FBSite, under a different bucketing
plan, or under a different execution layout (e.g. host fold vs the
device-resident Kahan fold, 1 device vs a sharded scenario axis) are
invalidated instead of silently served stale — no two of those
configurations can ever serve each other.
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path

from repro.core import planner
from repro.core.checkpoint import (CKPT_SCHEMA_VERSION, CheckpointSpec,
                                   atomic_write_text)
from repro.core.simulator import (SIM_SCHEMA_VERSION, SimParams,
                                  execution_mode, fault_fingerprint,
                                  flow_fingerprint, run_sweep_planned)
from repro.core.topology import FBSite, full_site_tag
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "sim_results.json"
TICKS = 100_000

#: every per-trace run is (LC/DC, always-on) on ONE site
_RUNS_PER_TRACE = 2


def _plan(site: FBSite, max_compiles: int) -> planner.SweepPlan:
    return planner.plan_sites([site] * _RUNS_PER_TRACE, max_compiles)


def _cache_meta(site: FBSite, ticks: int, max_compiles: int) -> dict:
    # "faults"/"flows" pin the default (all-off) fault and flow knobs
    # and "validate" the guard mode: results cached before either model
    # existed, or under different knob defaults, never serve a
    # fault-aware or flow-aware run. "ckpt_schema" records the
    # durability layer the run could have resumed through — NOT whether
    # checkpointing was on: checkpointing only observes a run
    # (bit-identical on or off, pinned by tests/test_durability.py), so
    # a checkpointed and an uncheckpointed run rightly share a cache
    # entry, but a resume through an incompatible checkpoint layout
    # can't have produced these results
    return {"sim_schema": SIM_SCHEMA_VERSION, "ticks": ticks,
            "site": dataclasses.asdict(site),
            "plan": _plan(site, max_compiles).fingerprint,
            "exec": execution_mode(n_scenarios=_RUNS_PER_TRACE),
            "faults": fault_fingerprint(), "flows": flow_fingerprint(),
            "validate": False, "ckpt_schema": CKPT_SCHEMA_VERSION}


def _cache_path(site: FBSite, ticks: int) -> Path:
    # non-default configurations get their own file so they coexist
    # with (rather than clobber) the default cache; the tag covers
    # EVERY FBSite field so distinct sites never share a file
    if site == FBSite() and ticks == TICKS:
        return OUT
    return OUT.with_name(f"sim_results_{full_site_tag(site)}_{ticks}.json")


def get_results(ticks: int = TICKS, force: bool = False,
                site: FBSite = FBSite(), max_compiles: int = 1,
                checkpoint: CheckpointSpec | None = None) -> dict:
    meta = _cache_meta(site, ticks, max_compiles)
    out = _cache_path(site, ticks)
    data = {"meta": meta, "ticks": ticks, "traces": {}}
    if out.exists() and not force:
        prev = json.loads(out.read_text())
        # pre-schema caches have no "meta" at all -> invalidated too
        if prev.get("meta") == meta:
            data = prev
    missing = [n for n in TRAFFIC_SPECS if n not in data["traces"]]
    if not missing:
        return data
    out.parent.mkdir(parents=True, exist_ok=True)
    # one planned B=2 sweep per missing trace: every call after the
    # first reuses the same cached compile (identical bucket hulls and
    # batch shapes), and the per-trace incremental save keeps an
    # interrupted 100k-tick run resumable
    for name in missing:
        spec = TRAFFIC_SPECS[name]
        t0 = time.time()
        # the optional CheckpointSpec rides through (per-trace tag so
        # traces don't prune each other); it does NOT join the cache
        # key — checkpointing is observation-only, bit-identical on/off
        cs = None if checkpoint is None else dataclasses.replace(
            checkpoint, tag=f"{checkpoint.tag}-{name}")
        lc, base = run_sweep_planned(
            [(SimParams(spec=spec, site=site, gating_enabled=True), 0),
             (SimParams(spec=spec, site=site, gating_enabled=False), 0)],
            ticks, max_compiles=max_compiles, checkpoint=cs)
        data["traces"][name] = {
            "lcdc": lc, "baseline": base,
            "wall_s": round(time.time() - t0, 1),
        }
        # atomic incremental save: a mid-run interrupt keeps every
        # finished trace servable instead of truncating the cache
        atomic_write_text(out, json.dumps(data, indent=1))
    return data
