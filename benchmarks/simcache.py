"""Shared simulation runner for the Fig 8/9/10 benchmarks: runs every
trace (LC/DC + always-on baseline) as ONE batched sweep — a single
compile + vmapped scan over the whole grid — and caches to results/."""
from __future__ import annotations

import json
import time
from pathlib import Path

from repro.core.simulator import SimParams, make_batch, run_sweep
from repro.core.traffic import TRAFFIC_SPECS

OUT = Path(__file__).resolve().parents[1] / "results" / "sim_results.json"
TICKS = 100_000


def get_results(ticks: int = TICKS, force: bool = False) -> dict:
    data = {"ticks": ticks, "traces": {}}
    if OUT.exists() and not force:
        prev = json.loads(OUT.read_text())
        if prev.get("ticks") == ticks:
            data = prev
    missing = [n for n in TRAFFIC_SPECS if n not in data["traces"]]
    if not missing:
        return data
    OUT.parent.mkdir(parents=True, exist_ok=True)
    # one B=2 sweep per missing trace: every call after the first reuses
    # the same cached compile (identical batch shape), and the per-trace
    # incremental save keeps an interrupted 100k-tick run resumable
    for name in missing:
        spec = TRAFFIC_SPECS[name]
        t0 = time.time()
        lc, base = run_sweep(make_batch(
            [(SimParams(spec=spec, gating_enabled=True), 0),
             (SimParams(spec=spec, gating_enabled=False), 0)]), ticks)
        data["traces"][name] = {
            "lcdc": lc, "baseline": base,
            "wall_s": round(time.time() - t0, 1),
        }
        OUT.write_text(json.dumps(data, indent=1))   # incremental save
    return data
