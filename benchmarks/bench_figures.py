"""One benchmark per paper table/figure (deliverable d).

  fig1  power-breakdown series per network design        (Sec II)
  fig7  traffic-generator CDF fidelity (Pearson r)       (Sec VI-A)
  fig8  partial network activation breakdown             (Sec VI-B)
  fig9  transceiver energy savings per trace             (Sec VI-B)
  fig10 packet latency LC/DC vs always-on                (Sec VI-B)
  fig11 whole-DC energy savings at 30/50/70% util        (Sec VI-B)
  ici   beyond-paper: LC/DC on the TPU ICI fabric
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import ici_gating
from repro.core.energy import dc_savings, power_breakdown_series
from repro.core.topology import all_designs
from repro.core.traffic import (TARGET_CDFS, TRAFFIC_SPECS,
                                pearson_vs_target, sample_flow_sizes,
                                sample_intervals)
from benchmarks.simcache import get_results


def bench_fig1_power_breakdown(report):
    t0 = time.time()
    rows = {}
    for d in all_designs():
        series = power_breakdown_series(d, util=0.30)
        name, _, frac = series[-1]
        rows[d.name] = frac
    avg_tx = float(np.mean([f["transceivers"] for f in rows.values()]))
    max_full = float(max(f["transceivers"] + f["phy"] + f["nic"]
                         for f in rows.values()))
    report("fig1_power_breakdown", time.time() - t0,
           f"avg_tx_frac={avg_tx:.3f} (paper ~0.20); "
           f"max_phy_nic_tx={max_full:.3f} (paper 'up to 0.46')")
    for k, f in rows.items():
        report(f"fig1[{k}]", 0.0,
               f"servers={f['servers']:.3f} tx={f['transceivers']:.3f} "
               f"nic={f['nic']:.3f} phy={f['phy']:.3f}")


def bench_fig7_traffic_cdfs(report):
    t0 = time.time()
    rs, ri = [], []
    for name, spec in TRAFFIC_SPECS.items():
        sizes = sample_flow_sizes(jax.random.PRNGKey(0), spec, 200_000)
        iat = sample_intervals(jax.random.PRNGKey(1), spec, 200_000)
        r_s = pearson_vs_target(np.asarray(sizes), TARGET_CDFS[name]["size"])
        r_i = pearson_vs_target(np.asarray(iat),
                                TARGET_CDFS[name]["interval"])
        rs.append(r_s)
        ri.append(r_i)
        report(f"fig7[{name}]", 0.0, f"r_size={r_s:.4f} r_interval={r_i:.4f}")
    report("fig7_traffic_cdfs", time.time() - t0,
           f"r_size in [{min(rs):.3f},{max(rs):.3f}] (paper 0.979-0.992); "
           f"r_interval in [{min(ri):.3f},{max(ri):.3f}] (paper 0.894-0.998)")


def bench_fig8_activation(report):
    t0 = time.time()
    data = get_results()
    halves = []
    for name, r in data["traces"].items():
        lc = r["lcdc"]
        halves.append(lc["half_off_frac"])
        hist = ",".join(f"{x:.2f}" for x in lc["on_frac_hist"])
        report(f"fig8[{name}]", 0.0,
               f"on_frac_hist(0-25|25-50|50-75|75-100%)={hist} "
               f"half_off={lc['half_off_frac']:.2f}")
    report("fig8_activation", time.time() - t0,
           f"avg_half_off={np.mean(halves):.3f} (paper: 0.87 avg; "
           f"Microsoft ~0.5)")


def bench_fig9_energy(report):
    t0 = time.time()
    data = get_results()
    saves = []
    for name, r in data["traces"].items():
        s = r["lcdc"]["switch_energy_savings_frac"]
        saves.append(s)
        report(f"fig9[{name}]", 0.0,
               f"switch_tier_savings={s:.3f} "
               f"node_on={r['lcdc']['node_link_on_frac']:.3f}")
    report("fig9_energy", time.time() - t0,
           f"avg={np.mean(saves):.3f} max={np.max(saves):.3f} "
           f"(paper: avg 0.60, max 0.68)")


def bench_fig10_latency(report):
    t0 = time.time()
    data = get_results()
    pens = []
    for name, r in data["traces"].items():
        pen = (r["lcdc"]["mean_latency_us"]
               / r["baseline"]["mean_latency_us"] - 1.0)
        pens.append(pen)
        report(f"fig10[{name}]", 0.0,
               f"lcdc={r['lcdc']['mean_latency_us']:.2f}us "
               f"base={r['baseline']['mean_latency_us']:.2f}us "
               f"penalty={pen*100:+.1f}%")
    report("fig10_latency", time.time() - t0,
           f"avg_penalty={np.mean(pens)*100:+.1f}% (paper: +6%)")


def bench_fig11_dc_energy(report):
    t0 = time.time()
    data = get_results()
    # Fig 11 input: the representative transceiver savings. The paper uses
    # its Fig 9 number (~60% -> on_frac ~0.4); we use our measured
    # switch-tier savings averaged over traces for the same arithmetic.
    on = float(np.mean([1.0 - r["lcdc"]["switch_energy_savings_frac"]
                        for r in data["traces"].values()]))
    for util, paper in [(0.30, "12%/21-27%"), (0.50, "13%/23%"),
                        (0.70, "12%/21%")]:
        res = dc_savings(on, util)["average"]
        report(f"fig11[util={util:.0%}]", 0.0,
               f"links_only={res.savings_links_only:.3f} "
               f"with_phy_nic={res.savings_with_phy_nic:.3f} "
               f"(paper {paper})")
    report("fig11_dc_energy", time.time() - t0,
           f"transceiver_on_frac_input={on:.3f}")


def bench_ici_gating(report):
    t0 = time.time()
    rows = ici_gating.analyze_all(idle_frac=0.0)
    if not rows:
        report("ici_gating", time.time() - t0, "no dry-run artifacts yet")
        return
    best = max(rows, key=lambda r: r["scheduled"]["ici_energy_savings"])
    worst = min(rows, key=lambda r: r["scheduled"]["ici_energy_savings"])
    avg = np.mean([r["scheduled"]["ici_energy_savings"] for r in rows])
    for r in rows:
        report(f"ici[{r['arch']}|{r['shape']}]", 0.0,
               f"duty={r['collective_duty']:.3f} "
               f"sched_save={r['scheduled']['ici_energy_savings']:.3f} "
               f"react_save={r['reactive']['ici_energy_savings']:.3f} "
               f"react_pen={r['reactive']['latency_penalty']:.3f}")
    report("ici_gating", time.time() - t0,
           f"avg_sched_savings={avg:.3f} best={best['arch']}|{best['shape']}"
           f"={best['scheduled']['ici_energy_savings']:.3f} "
           f"worst={worst['arch']}|{worst['shape']}"
           f"={worst['scheduled']['ici_energy_savings']:.3f}")
    # serving-idle sweep: decode steps are too short to cycle lasers
    # per-layer (t_layer ~ us vs 11 us on+off), so the serving win comes
    # from gating across idle gaps between requests (diurnal load).
    for idle in (0.3, 0.6):
        rows_i = ici_gating.analyze_all(idle_frac=idle)
        dec = [r for r in rows_i if r["shape"] in ("decode_32k",
                                                   "long_500k")]
        if dec:
            a = np.mean([r["scheduled"]["ici_energy_savings"] for r in dec])
            report(f"ici_idle[{idle:.0%}]", 0.0,
                   f"decode-cell avg sched savings={a:.3f}")


def bench_sweep_throughput(report):
    """Batched sweep engine canary: scen-ticks/s on a small
    heterogeneous-site grid through the hull-bucketing planner (the
    full serial-vs-batched and planner-vs-single-hull comparisons live
    in benchmarks/bench_sweep.py)."""
    from repro.core.simulator import (SimParams, grid_runs,
                                      run_sweep_planned)
    from repro.core.topology import FBSite
    small = FBSite(n_clusters=2, racks_per_cluster=8, servers_per_rack=8,
                   csw_per_cluster=2, n_fc=2, csw_ring_links=4,
                   fc_ring_links=8)
    ticks, t0 = 1_000, time.time()
    runs = [r for site in (FBSite(), small)
            for r in grid_runs(traces=("fb_hadoop", "microsoft"),
                               site=site)]           # 8 scenarios, 2 sites
    _, plan = run_sweep_planned(runs, ticks, max_compiles=2,
                                return_plan=True)
    dt = time.time() - t0
    report("sweep_throughput", dt,
           f"{len(runs)} scenarios x {ticks} ticks, "
           f"{plan['n_buckets']} hull buckets "
           f"(padded-compute savings "
           f"{plan['savings_vs_single_hull_frac']:.1%} vs single hull); "
           f"{len(runs) * ticks / dt:.0f} scen-ticks/s incl compile")


ALL = [bench_fig1_power_breakdown, bench_fig7_traffic_cdfs,
       bench_fig8_activation, bench_fig9_energy, bench_fig10_latency,
       bench_fig11_dc_energy, bench_sweep_throughput, bench_ici_gating]
