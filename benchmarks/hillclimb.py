import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Perf hillclimb harness (EXPERIMENTS.md SSPerf).

Runs one (arch x shape) cell through a named config VARIANT, re-lowers
with the dry-run accounting machinery, and appends the roofline terms to
results/hillclimb.json so each hypothesis -> change -> measure cycle is
logged mechanically.

  PYTHONPATH=src python -m benchmarks.hillclimb --arch kimi-k2-1t-a32b \
      --shape train_4k --variant moe_ps

Variants are config-level edits (dataclasses.replace) so the baseline
model code path stays untouched.
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.launch import hlo_analysis as hla
from repro.launch.dryrun import _acct_cfg, lower_cell
from repro.launch.mesh import make_production_mesh

OUT = Path(__file__).resolve().parents[1] / "results" / "hillclimb.json"

VARIANTS = {
    "baseline": {},
    # kimi train: EP combine via reduce-scatter into the d-sharded residual
    "moe_ps": {"moe_combine": "psum_scatter"},
    # granite train: ZeRO-2 (params replicated over data; no per-layer
    # weight all-gathers; optimizer state still sharded)
    "zero2": {"zero": 2},
    "zero2_moe_ps": {"zero": 2, "moe_combine": "psum_scatter"},
    # activation-sharding alternatives
    "act_seq": {"act_shard": "seq"},
    "act_none": {"act_shard": "none"},
    # serving: replicate params over data (no FSDP gathers per token)
    "serve_repl": {"fsdp": False},
    "serve_repl_noremat": {"fsdp": False, "remat": False},
    "noremat": {"remat": False},
    # bigger attention chunks (fewer scan steps, bigger tiles)
    "chunk4k": {"attn_chunk": 4096},
    # gradient accumulation: shrink activation/dispatch working set k-x
    # (weight all-gathers repeat per microbatch: t_coll rises)
    "micro4": {"microbatches": 4},
    "micro8": {"microbatches": 8},
    "micro8_ps": {"microbatches": 8, "moe_combine": "psum_scatter"},
    "micro4_ps": {"microbatches": 4, "moe_combine": "psum_scatter"},
    "cap1_ps": {"capacity_factor": 1.0, "moe_combine": "psum_scatter"},
    "zero2_seq": {"zero": 2, "act_shard": "seq"},
    # replicated activations + grad accum: no per-layer residual
    # all-gathers at all; microbatching keeps the replicated remat
    # residuals small
    "act_none_micro8": {"act_shard": "none", "microbatches": 8},
    "act_none_micro4": {"act_shard": "none", "microbatches": 4},
    "act_none_micro8_ps": {"act_shard": "none", "microbatches": 8,
                           "moe_combine": "psum_scatter"},
    "z2_none_micro4": {"zero": 2, "act_shard": "none", "microbatches": 4},
    "z2_none_micro8": {"zero": 2, "act_shard": "none", "microbatches": 8},
    # serving: shard_map flash-decode (local cache writes, psum combine)
    "decode_sp": {"decode_sp": True},
    "decode_sp_repl": {"decode_sp": True, "fsdp": False},
}


def run_variant(arch: str, shape_name: str, variant: str,
                note: str = "") -> dict:
    from repro.core import constants as C
    cfg = dataclasses.replace(get_config(arch), **VARIANTS[variant])
    shape = SHAPES[shape_name]
    mesh = make_production_mesh()
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "variant": variant,
           "note": note, "ok": False}
    try:
        compiled, _ = lower_cell(cfg, shape, mesh)
        rec["memory"] = hla.memory_stats(compiled)
        coll_full = hla.parse_collectives(compiled.as_text()).by_op()
        rec["collectives_scan"] = coll_full
        del compiled
        acct = {}
        for n in (1, 2):
            c2, _ = lower_cell(_acct_cfg(cfg, shape, n), shape, mesh,
                               donate=False)
            acct[n] = {
                "flops": hla.cost_stats(c2)["flops"],
                "bytes": hla.cost_stats(c2)["bytes_accessed"],
                "coll": hla.parse_collectives(c2.as_text()).total_link_bytes,
            }
            del c2
        from repro.models.model import _stack_plan
        _, n_scan, _ = _stack_plan(cfg)
        tot = {k: acct[1][k] + (n_scan - 1) * (acct[2][k] - acct[1][k])
               for k in ("flops", "bytes", "coll")}
        # the grad-accumulation scan hides its trip count from the
        # L1/L2 accounting: totals scale by the microbatch count
        tot = {k: v * max(cfg.microbatches, 1) for k, v in tot.items()}
        rec.update(
            ok=True,
            flops=tot["flops"], bytes=tot["bytes"], coll=tot["coll"],
            t_compute_s=tot["flops"] / C.TPU_PEAK_BF16_FLOPS,
            t_memory_s=tot["bytes"] / C.TPU_HBM_BW,
            t_collective_s=tot["coll"] / C.TPU_ICI_LINK_BW,
        )
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
    rec["wall_s"] = round(time.time() - t0, 1)

    hist = json.loads(OUT.read_text()) if OUT.exists() else []
    hist.append(rec)
    OUT.write_text(json.dumps(hist, indent=1))
    dom = max(("t_compute_s", "t_memory_s", "t_collective_s"),
              key=lambda k: rec.get(k, 0)) if rec["ok"] else "-"
    print(f"[{rec['wall_s']:6.1f}s] {arch} {shape_name} {variant:18s} "
          f"ok={rec['ok']} "
          + (f"t_comp={rec['t_compute_s']:.3f} t_mem={rec['t_memory_s']:.3f} "
             f"t_coll={rec['t_collective_s']:.3f} dom={dom} "
             f"temp={rec['memory']['temp_size_in_bytes']/2**30:.1f}GiB"
             if rec["ok"] else f"ERR {rec.get('error','')[:120]}"))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--note", default="")
    args = ap.parse_args()
    run_variant(args.arch, args.shape, args.variant, args.note)


if __name__ == "__main__":
    main()
