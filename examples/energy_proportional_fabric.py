"""The paper's feature, end to end: run the LC/DC data-center simulation
on one trace, then apply the same gating controller to a TPU training
step's ICI traffic (from the dry-run artifacts, if present).

  PYTHONPATH=src python examples/energy_proportional_fabric.py
"""
from repro.core import ici_gating
from repro.core.node_model import default_timing
from repro.core.simulator import (SimParams, run_sweep, run_sweep_planned,
                                  sweep_grid)
from repro.core.topology import FBSite
from repro.core.traffic import TRAFFIC_SPECS


def main():
    print("=== node level (Sec IV-C) ===")
    t = default_timing()
    print(f"TCP/IP+NIC budget {t.stack_ns} ns; laser {t.laser_on_ns} ns "
          f"+ CDR {t.cdr_ns:.1f} ns -> hidden={t.hidden} "
          f"(slack {t.slack_ns:.0f} ns)")

    print("\n=== data-center fabric (Fig 2 site, fb_hadoop, 30k us) ===")
    # LC/DC + always-on baseline as one 2-scenario batched sweep
    lc, base = run_sweep(sweep_grid(traces=("fb_hadoop",)), 30_000)
    print(f"switch-tier transceiver savings: "
          f"{lc['switch_energy_savings_frac']:.1%}")
    print(f"latency: {lc['mean_latency_us']:.2f} us vs "
          f"{base['mean_latency_us']:.2f} us "
          f"({lc['mean_latency_us']/base['mean_latency_us']-1:+.1%})")
    print(f"delay distribution (in-scan histogram): "
          f"p50 {lc['delay_p50_us']:.2f} / p95 {lc['delay_p95_us']:.2f} "
          f"/ p99 {lc['delay_p99_us']:.2f} us "
          f"(always-on p99 {base['delay_p99_us']:.2f} us)")
    print(f"delay attribution: queueing {lc['delay_queue_us']:.3f} us, "
          f"laser/CDR wake stalls {lc['delay_wake_stall_us']:.4f} us "
          f"({lc['wake_stall_frac']:.2%} of pkts), "
          f"ring detours {lc['delay_ring_us']:.3f} us")
    print(f"fraction of time >=half the gated links are off: "
          f"{lc['half_off_frac']:.0%}")

    print("\n=== fabric design comparison (hull-bucketed sweep, 10k us) ===")
    # heterogeneous sites through the planner: each hull bucket compiles
    # tight instead of padding everything to the worst site
    dense = FBSite(n_clusters=8, racks_per_cluster=16, csw_per_cluster=2,
                   n_fc=2, csw_ring_links=4, fc_ring_links=8)
    spec = TRAFFIC_SPECS["fb_hadoop"]
    res, plan = run_sweep_planned(
        [(SimParams(spec=spec), 0),
         (SimParams(spec=spec, site=dense), 0)],
        10_000, max_compiles=2, return_plan=True)
    print(f"{plan['n_buckets']} hull buckets, padded-compute savings "
          f"{plan['savings_vs_single_hull_frac']:.1%} vs one shared hull")
    for r in res:
        print(f"  {r['plan_hull']:18s} savings="
              f"{r['switch_energy_savings_frac']:.1%} "
              f"latency {r['mean_latency_us']:.2f} us "
              f"(bucket {r['plan_bucket']})")

    print("\n=== TPU ICI fabric (beyond-paper) ===")
    rows = ici_gating.analyze_all()
    if not rows:
        print("(no dry-run artifacts under results/dryrun; run "
              "`python -m repro.launch.dryrun --all` first)")
        return
    for r in rows[:6]:
        print(f"{r['arch']:22s} {r['shape']:12s} "
              f"collective duty={r['collective_duty']:.2f} "
              f"scheduled-gating savings="
              f"{r['scheduled']['ici_energy_savings']:.1%} "
              f"(reactive: {r['reactive']['ici_energy_savings']:.1%} at "
              f"{r['reactive']['latency_penalty']:.0%} stall)")
    print(f"... ({len(rows)} cells total; see benchmarks/run.py)")


if __name__ == "__main__":
    main()
