"""End-to-end driver: train a ~100M-param qwen3-family model with the
full production stack — deterministic data pipeline, AdamW + cosine
schedule, async atomic checkpoints, restart-safe resume, straggler
tracking.

  PYTHONPATH=src python examples/train_100m.py --steps 300
  # kill it mid-run and run again: it resumes from the last checkpoint.

On CPU each step is a few seconds; on a real accelerator bump
--global-batch/--seq-len to taste. The config is a genuine ~100M
parameter model (12L x 768, GQA 12/4, tied embeddings, 32k vocab).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.train.trainer import Trainer, TrainerConfig


def config_100m():
    base = get_config("qwen3-0.6b")
    return dataclasses.replace(
        base, name="qwen3-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv=4, d_head=64, d_ff=2048, vocab=32000, qk_norm=True,
        tie_embeddings=True, dtype=jax.numpy.float32, remat=False,
        fsdp=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_100m")
    ap.add_argument("--lr", type=float, default=6e-4)
    args = ap.parse_args()

    cfg = config_100m()
    n = cfg.n_params()
    print(f"model: {cfg.name}  ~{n/1e6:.0f}M params")
    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                      global_batch=args.global_batch)
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, total_steps=args.steps,
                         ckpt_every=25, log_every=10, peak_lr=args.lr)
    trainer = Trainer(cfg=cfg, tcfg=tcfg, data=data)
    state, start = trainer.restore_or_init()
    if start:
        print(f"resuming from checkpoint at step {start}")
    trainer.run(state, start)
    ms = trainer.metrics_log
    print(f"\ntrained steps {start}..{args.steps - 1}")
    if ms:
        print(f"loss: first={ms[0]['loss']:.4f} last={ms[-1]['loss']:.4f}")
        print(f"mean step time: "
              f"{sum(m['step_time_s'] for m in ms)/len(ms):.2f}s; "
              f"stragglers flagged: {ms[-1]['stragglers_total']}")


if __name__ == "__main__":
    main()
