"""Quickstart: train a tiny LM for 20 steps, then greedy-decode from it.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, batch_at
from repro.models import model as M
from repro.optim import make_optimizer
from repro.train.steps import make_train_step


def main():
    cfg = dataclasses.replace(reduced(get_config("qwen3-0.6b")), vocab=512)
    data = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8)
    key = jax.random.PRNGKey(0)

    params = M.init_params(cfg, key)
    opt_init, _ = make_optimizer(cfg)
    opt = opt_init(params)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=5))

    print(f"training {cfg.name}-reduced "
          f"({sum(p.size for p in jax.tree.leaves(params))/1e6:.1f}M params)")
    for i in range(20):
        params, opt, m = step(params, opt, batch_at(data, i),
                              jnp.asarray(i, jnp.int32))
        if i % 5 == 0 or i == 19:
            print(f"  step {i:3d} loss={float(m['loss']):.4f} "
                  f"lr={float(m['lr']):.2e}")

    # greedy decode 12 tokens from a prompt
    prompt = batch_at(data, 10_000)["tokens"][:1, :16]
    logits, cache = M.prefill(cfg, params, {"tokens": prompt})
    cache_full = M.init_cache(cfg, 1, 16 + 12, dtype=cfg.dtype)

    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                sl = [slice(None)] * dst.ndim
                sl[ax] = slice(0, src.shape[ax])
                return dst.at[tuple(sl)].set(src)
        return src

    cache = jax.tree.map(merge, cache_full, cache)
    tok = jnp.argmax(logits, -1)[:, None]
    out = [int(tok[0, 0])]
    dec = jax.jit(lambda p, c, t, po: M.decode_step(cfg, p, c, t, po))
    for t in range(16, 16 + 11):
        logits, cache = dec(params, cache, tok, jnp.full((1,), t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        out.append(int(tok[0, 0]))
    print("prompt tokens: ", prompt[0].tolist())
    print("decoded tokens:", out)


if __name__ == "__main__":
    main()
