"""Batched serving demo: prefill a batch of prompts, then decode them in
lock-step with the jitted serve step (the decode_32k cell in miniature).

  PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.models import model as M


def main():
    cfg = reduced(get_config("qwen3-8b"))
    k_params, k_data = jax.random.split(jax.random.PRNGKey(0))
    params = M.init_params(cfg, k_params)

    B, prompt_len, gen_len = 8, 24, 16
    max_len = prompt_len + gen_len
    prompts = jax.random.randint(k_data, (B, prompt_len), 0, cfg.vocab)

    t0 = time.perf_counter()
    logits, cache = jax.jit(
        lambda p, b: M.prefill(cfg, p, b))(params, {"tokens": prompts})
    cache_full = M.init_cache(cfg, B, max_len, dtype=cfg.dtype)

    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                sl = [slice(None)] * dst.ndim
                sl[ax] = slice(0, src.shape[ax])
                return dst.at[tuple(sl)].set(src)
        return src

    cache = jax.tree.map(merge, cache_full, cache)
    print(f"prefill {B}x{prompt_len} in {time.perf_counter()-t0:.2f}s")

    dec = jax.jit(lambda p, c, t, po: M.decode_step(cfg, p, c, t, po))
    tok = jnp.argmax(logits, -1)[:, None]
    toks = [tok]
    t0 = time.perf_counter()
    for t in range(prompt_len, max_len - 1):
        logits, cache = dec(params, cache, tok,
                            jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        toks.append(tok)
    dt = time.perf_counter() - t0
    n_tok = B * len(toks)
    print(f"decoded {len(toks)} steps x {B} streams "
          f"({n_tok} tokens) in {dt:.2f}s -> {n_tok/dt:.1f} tok/s on CPU")
    out = jnp.concatenate(toks, axis=1)
    for b in range(min(B, 3)):
        print(f"stream {b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
