"""Model assembler: init / train-loss / prefill / decode for every family.

Layer stacks are scanned (`lax.scan` over stacked params) so the HLO stays
compact at 61-88 layers; heterogeneous archs scan over their repeating
period (Jamba: 8-sublayer period x 4). Remat wraps the scanned body.

Entry points (all pure, jit/pjit-able):
    init_params(cfg, key)            -> params pytree
    train_loss(cfg, params, batch)   -> (loss, metrics)
    prefill(cfg, params, batch)      -> (last_logits, cache)
    decode_step(cfg, params, cache, token, pos) -> (logits, cache)
    init_cache(cfg, batch, cache_len)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv6 as rw
from repro.models.layers import (cross_entropy, embed_init, rms_norm,
                                 swiglu_apply, swiglu_init, unembed)
from repro.models.moe import DistContext


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------

def _layer_init(key, cfg, kind, ffn, dtype):
    """One transformer-ish layer: mixer + FFN (+ norms)."""
    k1, k2 = jax.random.split(key)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dtype)}
    if kind == "attn":
        p["attn"] = attn.attn_init(k1, cfg, dtype)
    elif kind == "mamba":
        p["mamba"] = mb.mamba_init(k1, cfg, dtype)
    elif kind == "rwkv":
        p["rwkv"] = rw.rwkv_init(k1, cfg, dtype)
    if kind != "rwkv":                       # rwkv carries its own channel mix
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
        if ffn == "moe":
            p["moe"] = moe_mod.moe_init(k2, cfg, dtype)
        else:
            p["mlp"] = swiglu_init(k2, cfg.d_model, cfg.d_ff, dtype,
                                   cfg.mlp_variant)
    else:
        p["ln2"] = jnp.ones((cfg.d_model,), dtype)
    return p


def _layer_apply(cfg, p, x, *, positions, dist, kernel_fns, kind, ffn,
                 cache=None, pos=None, want_cache=False):
    """Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    kf = (kernel_fns or {})
    new_cache = {}
    if kind == "attn":
        if cache is not None and pos is not None:          # decode
            if cfg.attn_type == "mla":
                out, new_cache = attn.mla_decode(p["attn"], cfg, h, cache, pos)
            elif cfg.decode_sp and dist is not None and dist.mesh is not None:
                out, new_cache = attn.gqa_decode_sp(p["attn"], cfg, h, cache,
                                                    pos, dist)
            else:
                out, new_cache = attn.gqa_decode(p["attn"], cfg, h, cache, pos)
        else:
            fwd = attn.mla_forward if cfg.attn_type == "mla" \
                else attn.gqa_forward
            out, kv = fwd(p["attn"], cfg, h, positions=positions,
                          kernel_fn=kf.get("attention"))
            if want_cache:
                if cfg.attn_type == "mla":
                    new_cache = {"c_kv": kv[0], "k_rope": kv[1]}
                else:
                    k, v = kv
                    if cfg.swa_window and k.shape[1] > cfg.swa_window:
                        # roll the tail into a window-sized cache aligned so
                        # slot (pos % window) matches gqa_decode's writes
                        T = k.shape[1]
                        W = cfg.swa_window
                        shift = T % W
                        k, v = k[:, -W:], v[:, -W:]
                        k = jnp.roll(k, shift, axis=1)
                        v = jnp.roll(v, shift, axis=1)
                    new_cache = {"k": k, "v": v}
        x = x + out
    elif kind == "mamba":
        out, state = mb.mamba_forward(p["mamba"], cfg, h, state=cache)
        new_cache = state if (want_cache or cache is not None) else {}
        x = x + out
    elif kind == "rwkv":
        st = cache or {"att_shift": jnp.zeros_like(h[:, 0]),
                       "wkv": jnp.zeros((h.shape[0], cfg.d_model //
                                         cfg.rwkv_head_dim,
                                         cfg.rwkv_head_dim,
                                         cfg.rwkv_head_dim), jnp.float32),
                       "cm_shift": jnp.zeros_like(h[:, 0])}
        out, att_shift, wkv = rw.time_mix(p["rwkv"], cfg, h, st["att_shift"],
                                          st["wkv"], kernel_fn=kf.get("wkv"))
        x = x + out
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        out2, cm_shift = rw.channel_mix(p["rwkv"], h2, st["cm_shift"])
        x = x + out2
        if want_cache or cache is not None:
            new_cache = {"att_shift": att_shift, "wkv": wkv,
                         "cm_shift": cm_shift}
        return x, new_cache, aux

    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if ffn == "moe":
        out2, aux = moe_mod.moe_apply(p["moe"], cfg, h2, dist)
    else:
        out2 = swiglu_apply(p["mlp"], h2)
    return x + out2, new_cache, aux


# ---------------------------------------------------------------------------
# Stack structure
# ---------------------------------------------------------------------------

def _stack_plan(cfg):
    """Returns (n_prefix, n_scan, period). The stack is `n_prefix` explicit
    layers followed by a scan over `n_scan` copies of `period` sublayers."""
    if cfg.mamba is not None:                      # hybrid: scan over periods
        assert cfg.n_layers % cfg.attn_period == 0
        return 0, cfg.n_layers // cfg.attn_period, cfg.attn_period
    if cfg.first_dense:
        return cfg.first_dense, cfg.n_layers - cfg.first_dense, 1
    return 0, cfg.n_layers, 1


def _kinds_for_period(cfg, n_prefix, period):
    """(kind, ffn) of each sublayer inside the scanned period."""
    return [(cfg.layer_kind(n_prefix + i), cfg.ffn_kind(n_prefix + i))
            for i in range(period)]


def init_params(cfg, key):
    dtype = cfg.dtype
    n_prefix, n_scan, period = _stack_plan(cfg)
    kinds = _kinds_for_period(cfg, n_prefix, period)
    k_emb, k_head, k_pre, k_stack = jax.random.split(key, 4)

    params: dict[str, Any] = {
        "embed": embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = embed_init(k_head, cfg.padded_vocab, cfg.d_model,
                                    dtype)
    for i in range(n_prefix):
        params[f"prefix{i}"] = _layer_init(
            jax.random.fold_in(k_pre, i), cfg, cfg.layer_kind(i),
            cfg.ffn_kind(i), dtype)

    def one_period(k):
        ks = jax.random.split(k, period)
        return {f"sub{i}": _layer_init(ks[i], cfg, kinds[i][0], kinds[i][1],
                                       dtype)
                for i in range(period)}

    params["stack"] = jax.vmap(one_period)(jax.random.split(k_stack, n_scan))
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def _sublayer_cache(cfg, kind, batch, cache_len, dtype):
    if kind == "attn":
        S = min(cache_len, cfg.swa_window) if cfg.swa_window else cache_len
        if cfg.attn_type == "mla":
            m = cfg.mla
            return {"c_kv": jnp.zeros((batch, S, m.kv_lora_rank), dtype),
                    "k_rope": jnp.zeros((batch, S, m.qk_rope_head_dim),
                                        dtype)}
        return {"k": jnp.zeros((batch, S, cfg.n_kv, cfg.d_head), dtype),
                "v": jnp.zeros((batch, S, cfg.n_kv, cfg.d_head), dtype)}
    if kind == "mamba":
        return mb.mamba_state_init(cfg, batch)
    if kind == "rwkv":
        return rw.rwkv_state_init(cfg, batch, dtype)
    return {}


def init_cache(cfg, batch, cache_len, dtype=None):
    dtype = dtype or cfg.dtype
    n_prefix, n_scan, period = _stack_plan(cfg)
    kinds = _kinds_for_period(cfg, n_prefix, period)
    cache: dict[str, Any] = {"pos_offset": jnp.zeros((batch,), jnp.int32)}
    for i in range(n_prefix):
        cache[f"prefix{i}"] = _sublayer_cache(cfg, cfg.layer_kind(i), batch,
                                              cache_len, dtype)
    one = {f"sub{i}": _sublayer_cache(cfg, kinds[i][0], batch, cache_len,
                                      dtype)
           for i in range(period)}
    cache["stack"] = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (n_scan,) + a.shape), one)
    return cache


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _embed_inputs(cfg, params, batch):
    """Returns (x, positions, targets, loss_mask)."""
    emb = params["embed"]
    if cfg.frontend == "audio_frames":
        x = batch["features"]
        B, T = x.shape[:2]
        return x, jnp.arange(T)[None, :], batch.get("targets"), \
            batch.get("mask")
    if cfg.frontend == "vision_patches" and "patches" in batch:
        tok_emb = emb[batch["tokens"]]
        x = jnp.concatenate([batch["patches"].astype(tok_emb.dtype), tok_emb],
                            axis=1)
        B, T = x.shape[:2]
        tgt = batch.get("targets")
        mask = None
        if tgt is not None:
            P = cfg.n_frontend_tokens
            pad = jnp.zeros((B, P), tgt.dtype)
            tgt = jnp.concatenate([pad, tgt], axis=1)
            mask = jnp.concatenate([jnp.zeros((B, P), bool),
                                    jnp.ones((B, T - P), bool)], axis=1)
        return x, jnp.arange(T)[None, :], tgt, mask
    tokens = batch["tokens"]
    x = emb[tokens]
    T = tokens.shape[1]
    return x, jnp.arange(T)[None, :], batch.get("targets"), None


def _run_stack(cfg, params, x, positions, dist, kernel_fns, want_cache,
               in_cache=None, pos=None):
    """Applies prefix layers then the scanned stack.
    Returns (x, cache_out, total_aux)."""
    n_prefix, n_scan, period = _stack_plan(cfg)
    kinds = _kinds_for_period(cfg, n_prefix, period)
    aux_total = jnp.zeros((), jnp.float32)
    cache_out: dict[str, Any] = {}

    for i in range(n_prefix):
        c_in = in_cache[f"prefix{i}"] if in_cache is not None else None
        x, c, aux = _layer_apply(
            cfg, params[f"prefix{i}"], x, positions=positions, dist=dist,
            kernel_fns=kernel_fns, kind=cfg.layer_kind(i),
            ffn=cfg.ffn_kind(i), cache=c_in or None, pos=pos,
            want_cache=want_cache)
        cache_out[f"prefix{i}"] = c
        aux_total += aux

    def period_body(x, xs):
        p_period, c_period = xs
        caches = {}
        aux_p = jnp.zeros((), jnp.float32)
        for i in range(period):
            sub_c = None
            if c_period is not None and f"sub{i}" in c_period and \
                    c_period[f"sub{i}"]:
                sub_c = c_period[f"sub{i}"]
            x, c, aux = _layer_apply(
                cfg, p_period[f"sub{i}"], x, positions=positions, dist=dist,
                kernel_fns=kernel_fns, kind=kinds[i][0], ffn=kinds[i][1],
                cache=sub_c, pos=pos, want_cache=want_cache)
            caches[f"sub{i}"] = c
            aux_p += aux
        return x, (caches, aux_p)

    def sharded_body(x, xs):
        x, out = period_body(x, xs)
        return _constrain_act(cfg, x, dist), out

    body = sharded_body
    if cfg.remat:
        body = jax.checkpoint(sharded_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    stack_cache = in_cache["stack"] if in_cache is not None else None
    xs = (params["stack"], stack_cache)
    if cfg.unroll:                       # FLOP-accounting mode: no while loop
        caches_l, aux_l = [], []
        for i in range(n_scan):
            xs_i = jax.tree.map(lambda a: a[i], xs)
            x, (c, a) = body(x, xs_i)
            caches_l.append(c)
            aux_l.append(a)
        caches = jax.tree.map(lambda *cs: jnp.stack(cs), *caches_l) \
            if caches_l and jax.tree.leaves(caches_l[0]) else caches_l[0]
        aux_per = jnp.stack(aux_l)
    else:
        x, (caches, aux_per) = jax.lax.scan(body, x, xs)
    cache_out["stack"] = caches
    return x, cache_out, aux_total + jnp.sum(aux_per)


def _constrain_act(cfg, x, dist):
    """Residual-stream sharding constraint between layers."""
    if dist is None or dist.mesh is None:
        return x
    from jax.sharding import PartitionSpec as P
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"
    b_ax = da if x.shape[0] % dist.data_size == 0 else None
    if cfg.act_shard == "seq" and x.shape[1] % dist.model_size == 0:
        spec = P(b_ax, dist.model_axis, None)
    elif cfg.act_shard == "dmodel" and x.shape[2] % dist.model_size == 0:
        spec = P(b_ax, None, dist.model_axis)
    else:
        spec = P(b_ax, None, None)
    return jax.lax.with_sharding_constraint(x, spec)


def _logits(cfg, params, x):
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(x, head)


def train_loss(cfg, params, batch, dist=None, kernel_fns=None):
    x, positions, targets, mask = _embed_inputs(cfg, params, batch)
    x = _constrain_act(cfg, x, dist)
    x, _, aux = _run_stack(cfg, params, x, positions, dist, kernel_fns,
                           want_cache=False)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    if dist is not None and dist.mesh is not None:
        from jax.sharding import PartitionSpec as P
        da = dist.data_axes if len(dist.data_axes) > 1 else "data"
        b_ax = da if logits.shape[0] % dist.data_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, P(b_ax, None, dist.model_axis))
    loss = cross_entropy(logits, targets, mask)
    total = loss + 0.01 * aux
    return total, {"ce_loss": loss, "aux_loss": aux}


def prefill(cfg, params, batch, dist=None, kernel_fns=None):
    x, positions, _, _ = _embed_inputs(cfg, params, batch)
    x, cache, _ = _run_stack(cfg, params, x, positions, dist, kernel_fns,
                             want_cache=True)
    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    cache["pos_offset"] = jnp.full((x.shape[0],), positions.shape[-1],
                                   jnp.int32)
    return logits[:, 0], cache


def decode_step(cfg, params, cache, token, pos, dist=None, kernel_fns=None):
    """token: (B,1) int32; pos: (B,) absolute position of `token`."""
    x = params["embed"][token]
    x, new_cache, _ = _run_stack(cfg, params, x, positions=pos[:, None],
                                 dist=dist, kernel_fns=kernel_fns,
                                 want_cache=False, in_cache=cache, pos=pos)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(cfg, params, x)
    new_cache["pos_offset"] = pos + 1
    return logits[:, 0], new_cache
