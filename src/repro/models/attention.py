"""Attention: GQA (qk-norm / sliding-window / bidirectional) and MLA.

Memory discipline: full-sequence attention never materializes a (T, T)
score matrix — it scans over KV chunks with an online softmax (this is
also the pure-jnp oracle for the Pallas flash kernel; see
repro/kernels/ref.py which reuses `chunked_attention`).

Decode paths:
  * GQA: (B, 1) query against a (B, S, n_kv, dh) cache (rolling window for
    SWA archs).
  * MLA: absorbed-weight latent attention against a (B, S, kv_lora) +
    (B, S, rope) cache (DeepSeek-style; cache is ~(256+32) floats/token
    instead of n_heads * 128).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import shard_map

from repro.models.layers import apply_rope, init_dense, rms_norm

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# GQA parameters
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype):
    d, H, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    ks = jax.random.split(key, 5)
    p = {
        "wq": init_dense(ks[0], d, H * dh, dtype),
        "wk": init_dense(ks[1], d, Hkv * dh, dtype),
        "wv": init_dense(ks[2], d, Hkv * dh, dtype),
        "wo": init_dense(ks[3], H * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def mla_init(key, cfg, dtype):
    m, d, H = cfg.mla, cfg.d_model, cfg.n_heads
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": init_dense(ks[0], d, m.q_lora_rank, dtype),
        "wuq": init_dense(ks[1], m.q_lora_rank, H * qk_head, dtype),
        "wdkv": init_dense(ks[2], d, m.kv_lora_rank, dtype),
        "wkr": init_dense(ks[3], d, m.qk_rope_head_dim, dtype),
        "wuk": init_dense(ks[4], m.kv_lora_rank, H * m.qk_nope_head_dim, dtype),
        "wuv": init_dense(ks[5], m.kv_lora_rank, H * m.v_head_dim, dtype),
        "wo": init_dense(ks[6], H * m.v_head_dim, d, dtype),
        "q_ln": jnp.ones((m.q_lora_rank,), dtype),
        "kv_ln": jnp.ones((m.kv_lora_rank,), dtype),
    }


def attn_init(key, cfg, dtype):
    return mla_init(key, cfg, dtype) if cfg.attn_type == "mla" \
        else gqa_init(key, cfg, dtype)


# ---------------------------------------------------------------------------
# Chunked (online-softmax) attention over full sequences
# ---------------------------------------------------------------------------

def chunked_attention(q, k, v, *, causal=True, swa_window=0,
                      chunk_q=1024, chunk_k=1024):
    """q: (B,T,H,dq), k: (B,S,H,dq), v: (B,S,H,dv) -> (B,T,H,dv).

    Scans KV in chunks with a running (max, denom, acc) so peak memory is
    O(chunk_q * chunk_k) per head. Assumes T == S when causal.
    """
    B, T, H, dq = q.shape
    S, dv = k.shape[1], v.shape[-1]
    scale = dq ** -0.5
    cq, ck = min(chunk_q, T), min(chunk_k, S)
    nq, nk = T // cq, S // ck
    assert T % cq == 0 and S % ck == 0, (T, S, cq, ck)

    qc = q.reshape(B, nq, cq, H, dq)
    kc = k.reshape(B, nk, ck, H, dq)
    vc = v.reshape(B, nk, ck, H, dv)
    q_pos = jnp.arange(T).reshape(nq, cq)
    k_pos = jnp.arange(S).reshape(nk, ck)

    def q_step(_, qi):
        qb, qp = qi                                   # (B,cq,H,dq), (cq,)
        qb32 = qb.astype(jnp.float32) * scale

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb, kp = ki
            s = jnp.einsum("bqhd,bkhd->bhqk", qb32, kb.astype(jnp.float32))
            mask = jnp.ones((cq, ck), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if swa_window:
                mask &= qp[:, None] - kp[None, :] < swa_window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, H, cq), NEG_INF, jnp.float32),
                jnp.zeros((B, H, cq), jnp.float32),
                jnp.zeros((B, H, cq, dv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_step, init,
            (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4), k_pos))
        out = acc / jnp.maximum(l, 1e-30)[..., None]     # (B,H,cq,dv)
        return None, out.transpose(0, 2, 1, 3)           # (B,cq,H,dv)

    _, out = jax.lax.scan(q_step, None,
                          (qc.transpose(1, 0, 2, 3, 4), q_pos))
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, T, H, dv)
    return out.astype(v.dtype)


def _repeat_kv(x, n_rep):
    if n_rep == 1:
        return x
    B, S, Hkv, dh = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :],
                            (B, S, Hkv, n_rep, dh)).reshape(B, S, Hkv * n_rep, dh)


# ---------------------------------------------------------------------------
# GQA apply: full-sequence (train / prefill) and decode
# ---------------------------------------------------------------------------

def gqa_forward(p, cfg, x, *, positions, kernel_fn=None):
    """Full-sequence attention. x: (B,T,d). Returns (out, (k_cache, v_cache))."""
    B, T, _ = x.shape
    H, Hkv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (x @ p["wq"]).reshape(B, T, H, dh)
    k = (x @ p["wk"]).reshape(B, T, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, T, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    kf, vf = _repeat_kv(k, H // Hkv), _repeat_kv(v, H // Hkv)
    if kernel_fn is not None:
        out = kernel_fn(q, kf, vf, causal=cfg.causal,
                        swa_window=cfg.swa_window)
    else:
        out = chunked_attention(q, kf, vf, causal=cfg.causal,
                                swa_window=cfg.swa_window,
                                chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
    return out.reshape(B, T, H * dh) @ p["wo"], (k, v)


def gqa_decode(p, cfg, x, cache, pos):
    """One-token decode. x: (B,1,d); cache: dict(k,v: (B,S,Hkv,dh)); pos: (B,).

    For SWA archs the cache is a rolling window of size cfg.swa_window and
    writes go to pos % window.
    """
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    S = cache["k"].shape[1]
    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

    # Scatter one row per stream (writes O(B*Hkv*dh) bytes, not the whole
    # cache; with donated caches XLA updates in place).
    write_idx = pos % S if cfg.swa_window else pos
    rows = jnp.arange(B)
    kc = cache["k"].at[rows, write_idx].set(k[:, 0])
    vc = cache["v"].at[rows, write_idx].set(v[:, 0])

    kf, vf = _repeat_kv(kc, H // Hkv), _repeat_kv(vc, H // Hkv)
    s = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32) * dh ** -0.5,
                   kf.astype(jnp.float32))
    idx = jnp.arange(S)
    valid = idx[None, :] <= pos[:, None]
    if cfg.swa_window:
        # rolling cache: once pos >= S-1 every slot holds a live in-window
        # entry; before that only slots 0..pos have been written.
        valid = valid | (pos[:, None] >= S - 1)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", prob, vf.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, H * dh)
    return out @ p["wo"], {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# MLA apply
# ---------------------------------------------------------------------------

def mla_forward(p, cfg, x, *, positions, kernel_fn=None):
    """Full-sequence MLA (naive/un-absorbed). Returns (out, latent cache)."""
    m = cfg.mla
    B, T, _ = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    cq = rms_norm(x @ p["wdq"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, T, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(x @ p["wdkv"], p["kv_ln"], cfg.norm_eps)   # (B,T,r_kv)
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                        cfg.rope_theta)                        # (B,T,1,dr)
    k_nope = (c_kv @ p["wuk"]).reshape(B, T, H, dn)
    v = (c_kv @ p["wuv"]).reshape(B, T, H, dv)

    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, T, H, dr))],
                         axis=-1)
    if kernel_fn is not None:
        out = kernel_fn(qf, kf, v, causal=cfg.causal)
    else:
        out = chunked_attention(qf, kf, v, causal=cfg.causal,
                                chunk_q=cfg.attn_chunk, chunk_k=cfg.attn_chunk)
    out = out.reshape(B, T, H * dv) @ p["wo"]
    return out, (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, cfg, x, cache, pos):
    """Absorbed-weight MLA decode: cache holds (c_kv, k_rope) only."""
    m = cfg.mla
    B = x.shape[0]
    H = cfg.n_heads
    dn, dr, dv, r = (m.qk_nope_head_dim, m.qk_rope_head_dim,
                     m.v_head_dim, m.kv_lora_rank)
    S = cache["c_kv"].shape[1]

    cq = rms_norm(x @ p["wdq"], p["q_ln"], cfg.norm_eps)
    q = (cq @ p["wuq"]).reshape(B, 1, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos[:, None], cfg.rope_theta)

    c_new = rms_norm(x @ p["wdkv"], p["kv_ln"], cfg.norm_eps)  # (B,1,r)
    kr_new = apply_rope((x @ p["wkr"])[:, :, None, :], pos[:, None],
                        cfg.rope_theta)[:, :, 0, :]            # (B,1,dr)

    rows = jnp.arange(B)
    c_kv = cache["c_kv"].at[rows, pos].set(c_new[:, 0])
    k_rope = cache["k_rope"].at[rows, pos].set(kr_new[:, 0])

    # Absorb W_uk into q: q_lat[b,h,r] = sum_n q_nope[b,h,n] * wuk[r, h*dn+n]
    wuk = p["wuk"].reshape(r, H, dn)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope.astype(jnp.float32),
                       wuk.astype(jnp.float32))
    scale = (dn + dr) ** -0.5
    s = (jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv.astype(jnp.float32)) +
         jnp.einsum("bqhr,bsr->bhqs", q_rope.astype(jnp.float32),
                    k_rope.astype(jnp.float32))) * scale
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", prob, c_kv.astype(jnp.float32))
    wuv = p["wuv"].reshape(r, H, dv)
    out = jnp.einsum("bqhr,rhv->bqhv", o_lat, wuv.astype(jnp.float32))
    out = out.astype(x.dtype).reshape(B, 1, H * dv)
    return out @ p["wo"], {"c_kv": c_kv, "k_rope": k_rope}


# ---------------------------------------------------------------------------
# Sequence-parallel decode (flash-decoding style, shard_map)
# ---------------------------------------------------------------------------

def gqa_decode_sp(p, cfg, x, cache, pos, dist):
    """One-token GQA decode with the KV cache sharded over (batch x seq).

    The plain GSPMD path scatters the new (k, v) row across the
    seq-sharded cache, which the partitioner can only realize by fully
    rematerializing (all-gathering) the cache every layer. Here the
    update and the attention run inside shard_map: each seq shard writes
    the new row iff `pos` lands in its range (a local masked write) and
    computes a partial (max, denom, weighted-value); the combine is one
    tiny psum per head. Per-layer collective volume drops from O(cache)
    to O(B*H*dh).
    """
    B = x.shape[0]
    H, Hkv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    S = cache["k"].shape[1]
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"
    ma = dist.model_axis
    m = dist.model_size

    q = (x @ p["wq"]).reshape(B, 1, H, dh)
    k = (x @ p["wk"]).reshape(B, 1, Hkv, dh)
    v = (x @ p["wv"]).reshape(B, 1, Hkv, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)

    from jax.sharding import PartitionSpec as P

    def local_attend(q, k_new, v_new, kc, vc, pos):
        # kc/vc: (B_loc, S_loc, Hkv, dh); this shard covers seq range
        # [j*S_loc, (j+1)*S_loc)
        S_loc = kc.shape[1]
        j = jax.lax.axis_index(ma)
        s0 = j * S_loc
        idx = jnp.arange(S_loc)[None, :]
        # masked local write of the new row
        local = (pos[:, None] >= s0) & (pos[:, None] < s0 + S_loc)
        li = jnp.clip(pos[:, None] - s0, 0, S_loc - 1)
        onrow = (idx == li) & local                    # (B_loc, S_loc)
        kc = jnp.where(onrow[..., None, None], k_new, kc)
        vc = jnp.where(onrow[..., None, None], v_new, vc)

        kf = _repeat_kv(kc, H // Hkv)
        vf = _repeat_kv(vc, H // Hkv)
        s = jnp.einsum("bqhd,bshd->bhqs",
                       q.astype(jnp.float32) * dh ** -0.5,
                       kf.astype(jnp.float32))
        valid = (s0 + idx) <= pos[:, None]
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_loc = jnp.max(s, axis=-1)                    # (B,H,1)
        m_glob = jax.lax.pmax(m_loc, ma)
        e = jnp.exp(s - m_glob[..., None])
        l_loc = jnp.sum(e, axis=-1)
        acc = jnp.einsum("bhqs,bshd->bqhd", e, vf.astype(jnp.float32))
        l_glob = jax.lax.psum(l_loc, ma)
        acc = jax.lax.psum(acc, ma)
        out = acc / jnp.maximum(l_glob, 1e-30).transpose(0, 2, 1)[..., None]
        return out.astype(q.dtype), kc, vc

    out, kc, vc = shard_map(
        local_attend, mesh=dist.mesh,
        in_specs=(P(da, None, None, None), P(da, None, None, None),
                  P(da, None, None, None), P(da, ma, None, None),
                  P(da, ma, None, None), P(da)),
        out_specs=(P(da, None, None, None), P(da, ma, None, None),
                   P(da, ma, None, None)),
        check_vma=False,
    )(q, k, v, cache["k"], cache["v"], pos)
    out = out.reshape(B, 1, H * dh) @ p["wo"]
    return out, {"k": kc, "v": vc}
