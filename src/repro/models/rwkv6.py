"""RWKV-6 "Finch": attention-free time-mix with data-dependent decay.

Faithful to arXiv:2404.05892: token-shift with data-dependent lerp (the
5-way LoRA), per-channel data-dependent decay w = exp(-exp(.)), bonus u,
multi-head wkv state (dh x dh per head), per-head group norm, and a
squared-ReLU channel mix. Norms are RMSNorm (deviation from the
reference LayerNorm; documented in DESIGN.md).

The model path uses the sequential `wkv_scan` (one lax.scan over time,
O(1) state). The chunked MXU-friendly formulation lives in
repro/kernels/rwkv6_wkv.py (Pallas) with its oracle in kernels/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense

LORA_DIM = 32
DECAY_LORA_DIM = 64


def rwkv_init(key, cfg, dtype):
    d = cfg.d_model
    dh = cfg.rwkv_head_dim
    H = d // dh
    ks = jax.random.split(key, 12)
    p = {
        # time-mix (attention analogue)
        "maa_x": jnp.zeros((d,), dtype),
        "maa_wkvrg": jnp.zeros((5, d), dtype),
        "tm_w1": init_dense(ks[0], d, 5 * LORA_DIM, dtype),
        "tm_w2": (jax.random.normal(ks[1], (5, LORA_DIM, d)) *
                  LORA_DIM ** -0.5).astype(dtype),
        "w0": jnp.full((d,), -1.0, dtype),       # base decay logit
        "td_w1": init_dense(ks[2], d, DECAY_LORA_DIM, dtype),
        "td_w2": init_dense(ks[3], DECAY_LORA_DIM, d, dtype),
        "u": (jax.random.normal(ks[4], (H, dh)) * 0.1).astype(dtype),
        "wr": init_dense(ks[5], d, d, dtype),
        "wk": init_dense(ks[6], d, d, dtype),
        "wv": init_dense(ks[7], d, d, dtype),
        "wg": init_dense(ks[8], d, d, dtype),
        "wo": init_dense(ks[9], d, d, dtype),
        "gn_w": jnp.ones((d,), dtype),
        # channel mix
        "cm_maa_k": jnp.zeros((d,), dtype),
        "cm_maa_r": jnp.zeros((d,), dtype),
        "cm_wk": init_dense(ks[10], d, cfg.d_ff, dtype),
        "cm_wv": init_dense(ks[11], cfg.d_ff, d, dtype),
        "cm_wr": init_dense(jax.random.fold_in(key, 99), d, d, dtype),
    }
    return p


def _group_norm(x, weight, H, eps=1e-5):
    """Per-head normalization. x: (..., H*dh)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], H, shp[-1] // H).astype(jnp.float32)
    mu = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mu) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(shp) * weight.astype(jnp.float32)).astype(x.dtype)


def _ddlerp(p, x, sx):
    """Data-dependent token-shift lerp -> (xw, xk, xv, xr, xg)."""
    xxx = x + sx * p["maa_x"]
    lora = jnp.tanh(xxx @ p["tm_w1"])
    lora = lora.reshape(*lora.shape[:-1], 5, LORA_DIM)
    deltas = jnp.einsum("...fk,fkd->...fd", lora, p["tm_w2"])
    mix = p["maa_wkvrg"] + deltas          # (..., 5, d)
    return tuple(x + sx * mix[..., i, :] for i in range(5))


def wkv_scan(r, k, v, w, u, state):
    """Sequential wkv recurrence.

    r,k,v,w: (B,T,H,dh); u: (H,dh); state: (B,H,dh,dh) [k-dim x v-dim].
    Returns (y (B,T,H,dh), final state). fp32 internally.
    """
    rf, kf, vf, wf = (a.astype(jnp.float32).transpose(1, 0, 2, 3)
                      for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp                    # (B,H,dh)
        kv = kt[..., :, None] * vt[..., None, :]     # (B,H,dh,dh)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + uf[..., None] * kv)
        s = wt[..., None] * s + kv
        return s, y

    state, y = jax.lax.scan(step, state.astype(jnp.float32),
                            (rf, kf, vf, wf))
    return y.transpose(1, 0, 2, 3).astype(r.dtype), state


def time_mix(p, cfg, x, shift_state, wkv_state, kernel_fn=None):
    """x: (B,T,d). shift_state: (B,d) (last token of previous segment).
    Returns (out, new_shift_state, new_wkv_state)."""
    B, T, d = x.shape
    dh = cfg.rwkv_head_dim
    H = d // dh
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    sx = prev - x
    xw, xk, xv, xr, xg = _ddlerp(p, x, sx)

    r = (xr @ p["wr"]).reshape(B, T, H, dh)
    k = (xk @ p["wk"]).reshape(B, T, H, dh)
    v = (xv @ p["wv"]).reshape(B, T, H, dh)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(-jnp.exp((p["w0"] + jnp.tanh(xw @ p["td_w1"]) @ p["td_w2"])
                         .astype(jnp.float32))).reshape(B, T, H, dh)

    wkv = kernel_fn or wkv_scan
    y, wkv_state = wkv(r, k, v, w.astype(r.dtype), p["u"], wkv_state)
    y = _group_norm(y.reshape(B, T, d), p["gn_w"], H)
    out = (y * g) @ p["wo"]
    return out, x[:, -1, :], wkv_state


def channel_mix(p, x, shift_state):
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    sx = prev - x
    xk = x + sx * p["cm_maa_k"]
    xr = x + sx * p["cm_maa_r"]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"]), x[:, -1, :]


def rwkv_state_init(cfg, batch, dtype=None):
    """Per-layer recurrent state (stacked over layers by the assembler).
    Token-shift states live in the model dtype (they concat with
    activations); the wkv state stays fp32 for the recurrence."""
    d, dh = cfg.d_model, cfg.rwkv_head_dim
    H = d // dh
    dtype = dtype or cfg.dtype
    return {
        "att_shift": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "cm_shift": jnp.zeros((batch, d), dtype),
    }
