"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Two execution modes, selected by ``DistContext``:

* pure (single-device / smoke tests): global sort-based dispatch.
* distributed (inside the jitted step): ``shard_map`` over
  ('data', 'model') with one of two expert layouts:
    - ``ep``: experts sharded over the model axis (E % model == 0, e.g.
      Kimi 384/16, Jamba 16/16). Each (data, model)-device computes
      <its data-shard tokens> x <its experts>; the combine is a psum over
      'model'. Expert weights are FSDP-sharded over 'data' on the d_ff
      dim and explicitly all-gathered per layer (the FSDP all-gather is
      visible in the HLO, which the roofline/ICI-gating analyses read).
    - ``tp``: d_ff sharded over the model axis (E < model, e.g. Mixtral
      8e on a 16-way axis). All experts on every model shard, partial
      d_ff; combine is a psum over 'model'.

Token-choice top-k routing with softmax-renormalized gates, capacity
clamp (capacity_factor over the mean load) and a load-balancing aux loss.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map

from repro.models.layers import init_dense


@dataclass(frozen=True)
class DistContext:
    mesh: object                 # jax.sharding.Mesh | None
    data_axes: tuple = ("data",)  # ('pod','data') when multi-pod
    model_axis: str = "model"

    @property
    def model_size(self) -> int:
        return self.mesh.shape[self.model_axis] if self.mesh else 1

    @property
    def data_size(self) -> int:
        if not self.mesh:
            return 1
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n


def moe_init(key, cfg, dtype):
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    ks = jax.random.split(key, 4)
    return {
        "router": init_dense(ks[0], d, E, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f)) * d ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (E, d, f)) * d ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, f, d)) * f ** -0.5).astype(dtype),
    }


def ep_mode(cfg, dist: DistContext) -> str:
    if dist.model_size > 1 and cfg.expert_parallel and \
            cfg.n_experts % dist.model_size == 0:
        return "ep"
    return "tp"


def _top_k_gates(logits, k):
    """(S, E) fp32 -> (gates (S,k), idx (S,k), me (E,), ce (E,)).

    me/ce are the per-shard mean router prob / top-1 dispatch fraction;
    the Switch aux loss E*sum(me*ce) is formed AFTER averaging them
    globally (pmean over data) so distributed == single-device exactly.
    """
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    E = logits.shape[-1]
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32), axis=0)
    return gates, idx, me, ce


def _aux_loss(me, ce):
    return me.shape[-1] * jnp.sum(me * ce)


def _dispatch_compute_combine(x, gates, idx, w_gate, w_up, w_down,
                              e_lo, n_local, capacity):
    """Sort-based dispatch of (S,d) tokens to `n_local` experts
    [e_lo, e_lo+n_local), expert FFN, weighted combine. Static shapes.
    """
    S, d = x.shape
    K = idx.shape[1]
    flat_e = idx.reshape(-1)                        # (S*K,)
    flat_w = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(S), K)

    local = (flat_e >= e_lo) & (flat_e < e_lo + n_local)
    rel_e = jnp.where(local, flat_e - e_lo, n_local)  # overflow bucket
    order = jnp.argsort(rel_e, stable=True)
    sorted_e = rel_e[order]
    sorted_t = flat_t[order]
    sorted_w = flat_w[order]

    counts = jnp.zeros(n_local + 1, jnp.int32).at[sorted_e].add(1)
    offsets = jnp.concatenate([jnp.zeros(1, jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(S * K, dtype=jnp.int32) - offsets[sorted_e]
    keep = (sorted_e < n_local) & (pos < capacity)

    slot = jnp.where(keep, sorted_e * capacity + pos, n_local * capacity)
    buf = jnp.zeros((n_local * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(jnp.where(keep[:, None], x[sorted_t], 0.0))
    buf = buf[:-1].reshape(n_local, capacity, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate)) * \
        jnp.einsum("ecd,edf->ecf", buf, w_up)
    y_buf = jnp.einsum("ecf,efd->ecd", h, w_down).reshape(-1, d)

    contrib = jnp.where(keep[:, None], y_buf[jnp.minimum(slot, len(y_buf) - 1)]
                        * sorted_w[:, None].astype(x.dtype), 0.0)
    return jnp.zeros((S, d), x.dtype).at[sorted_t].add(contrib)


def _capacity(cfg, n_tokens, n_experts):
    c = int(n_tokens * cfg.top_k / n_experts * cfg.capacity_factor) + 1
    return -(-c // 8) * 8  # round up to 8


def moe_apply_pure(p, cfg, x):
    """Single-device reference. x: (B,T,d)."""
    B, T, d = x.shape
    xf = x.reshape(B * T, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    gates, idx, me, ce = _top_k_gates(logits, cfg.top_k)
    cap = _capacity(cfg, B * T, cfg.n_experts)
    y = _dispatch_compute_combine(xf, gates, idx, p["w_gate"], p["w_up"],
                                  p["w_down"], 0, cfg.n_experts, cap)
    return y.reshape(B, T, d), _aux_loss(me, ce)


def moe_apply_dist(p, cfg, x, dist: DistContext):
    """Distributed MoE via shard_map. x: (B,T,d) sharded (data, None, None);
    when the batch doesn't divide the data axes (decode with B=1) tokens
    are replicated over data and only the model axis does real work."""
    B, T, d = x.shape
    mode = ep_mode(cfg, dist)
    m = dist.model_size
    da, ma = dist.data_axes, dist.model_axis
    b_shardable = B % dist.data_size == 0
    x_spec = P(da, None, None) if b_shardable else P(None, None, None)
    E, f = cfg.n_experts, cfg.d_expert
    # FSDP shards the expert d_ff dim over the (composite) data axes when
    # divisible, else over 'data' alone.
    fsdp_ax = da if f % dist.data_size == 0 else ("data",)
    if mode == "ep":
        e_spec = P(ma, None, fsdp_ax)
        e_spec_dn = P(ma, fsdp_ax, None)
    else:   # tp: d_ff over model, FSDP over data on the d_model dim
        e_spec = P(None, "data", ma)
        e_spec_dn = P(None, ma, "data")

    def local_moe(xl, router, wg, wu, wd):
        S_loc = xl.shape[0] * xl.shape[1]
        xf = xl.reshape(S_loc, d)
        if mode == "ep":
            # FSDP all-gather of this model-shard's expert weights
            wg = jax.lax.all_gather(wg, fsdp_ax, axis=2, tiled=True)
            wu = jax.lax.all_gather(wu, fsdp_ax, axis=2, tiled=True)
            wd = jax.lax.all_gather(wd, fsdp_ax, axis=1, tiled=True)
            n_local, e_lo = E // m, jax.lax.axis_index(ma) * (E // m)
        else:
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
            n_local, e_lo = E, 0
        logits = xf.astype(jnp.float32) @ router
        gates, idx, me, ce = _top_k_gates(logits, cfg.top_k)
        cap = _capacity(cfg, S_loc, E)
        y = _dispatch_compute_combine(xf, gates, idx, wg, wu, wd,
                                      e_lo, n_local, cap)
        if cfg.moe_combine == "psum_scatter" and d % m == 0:
            # combine straight into the d-sharded residual layout: half
            # the ring traffic of a full all-reduce, and the downstream
            # act_shard="dmodel" constraint needs exactly this shard.
            y = jax.lax.psum_scatter(y, ma, scatter_dimension=1,
                                     tiled=True)
        else:
            y = jax.lax.psum(y, ma)
        if b_shardable:
            me = jax.lax.pmean(me, da)
            ce = jax.lax.pmean(ce, da)
        return y.reshape(xl.shape[0], xl.shape[1], -1), \
            _aux_loss(me, ce)

    in_specs = (x_spec, P(), e_spec, e_spec, e_spec_dn)
    if cfg.moe_combine == "psum_scatter" and d % m == 0:
        y_spec = P(*(list(x_spec)[:2] + [ma]))
    else:
        y_spec = x_spec
    out_specs = (y_spec, P())
    y, aux = shard_map(
        local_moe, mesh=dist.mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False)(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux


def moe_apply(p, cfg, x, dist: DistContext | None = None):
    if dist is None or dist.mesh is None:
        return moe_apply_pure(p, cfg, x)
    return moe_apply_dist(p, cfg, x, dist)


def moe_param_specs(cfg, dist: DistContext) -> dict:
    """PartitionSpecs matching moe_apply_dist's in_specs."""
    mode = ep_mode(cfg, dist)
    ma = dist.model_axis
    fsdp_ax = dist.data_axes if cfg.d_expert % dist.data_size == 0 \
        else ("data",)
    if mode == "ep":
        return {
            "router": P(),
            "w_gate": P(ma, None, fsdp_ax),
            "w_up": P(ma, None, fsdp_ax),
            "w_down": P(ma, fsdp_ax, None),
        }
    return {
        "router": P(),
        "w_gate": P(None, "data", ma),
        "w_up": P(None, "data", ma),
        "w_down": P(None, ma, "data"),
    }
