"""Mamba (S6) selective-state-space block for the Jamba hybrid.

Faithful to the S6 recurrence: input-dependent (dt, B, C), A = -exp(A_log),
ZOH discretization dA = exp(dt*A), dB = dt*B. The time scan is a single
``lax.scan`` carrying h: (B, d_inner, d_state); per-step tensors are
sliced inside the body so the (B, T, d_inner, d_state) discretized tensor
is never materialized (the memory trick of the paper's hardware-aware
kernel, expressed at the XLA level).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import init_dense


def mamba_dims(cfg):
    mc = cfg.mamba
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, mc.d_state, mc.d_conv


def mamba_init(key, cfg, dtype):
    d = cfg.d_model
    d_in, dt_rank, d_state, d_conv = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    A = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                         (d_in, d_state))
    return {
        "in_proj": init_dense(ks[0], d, 2 * d_in, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_in)) *
                   d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": init_dense(ks[2], d_in, dt_rank + 2 * d_state, dtype),
        "dt_proj": init_dense(ks[3], dt_rank, d_in, dtype),
        "dt_bias": jnp.zeros((d_in,), dtype),
        "A_log": jnp.log(A),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": init_dense(ks[4], d_in, d, dtype),
    }


def _causal_conv(x, w, b):
    """x: (B,T,d_in); w: (d_conv, d_in) depthwise causal conv."""
    d_conv = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i] for i in range(d_conv))
    return out + b


def ssm_scan(u, dt, Bs, Cs, A, D, h0):
    """Selective scan. u, dt: (B,T,d_in); Bs, Cs: (B,T,d_state);
    A: (d_in, d_state); h0: (B, d_in, d_state). Returns (y, hT)."""
    uf = u.astype(jnp.float32).transpose(1, 0, 2)
    dtf = dt.astype(jnp.float32).transpose(1, 0, 2)
    Bf = Bs.astype(jnp.float32).transpose(1, 0, 2)
    Cf = Cs.astype(jnp.float32).transpose(1, 0, 2)

    def step(h, inp):
        ut, dtt, bt, ct = inp
        dA = jnp.exp(dtt[..., None] * A)                     # (B,d_in,N)
        dBu = (dtt * ut)[..., None] * bt[:, None, :]         # (B,d_in,N)
        h = dA * h + dBu
        y = jnp.einsum("bdn,bn->bd", h, ct) + D * ut
        return h, y

    hT, y = jax.lax.scan(step, h0.astype(jnp.float32), (uf, dtf, Bf, Cf))
    return y.transpose(1, 0, 2).astype(u.dtype), hT


def mamba_forward(p, cfg, x, state=None):
    """x: (B,T,d). state: None (fresh) or dict(conv (B,d_conv-1,d_in),
    h (B,d_in,d_state)) for segment continuation. Returns (out, new state).
    """
    B, T, _ = x.shape
    d_in, dt_rank, d_state, d_conv = mamba_dims(cfg)
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)

    if state is not None:
        ctx = jnp.concatenate([state["conv"].astype(xs.dtype), xs], axis=1)
        conv_in = ctx[:, -(T + d_conv - 1):, :]
        pad_ctx = conv_in
        out = sum(pad_ctx[:, i:i + T, :] * p["conv_w"][i]
                  for i in range(d_conv))
        xs_c = jax.nn.silu(out + p["conv_b"])
        new_conv = ctx[:, -(d_conv - 1):, :]
        h0 = state["h"]
    else:
        xs_c = jax.nn.silu(_causal_conv(xs, p["conv_w"], p["conv_b"]))
        new_conv = jnp.concatenate(
            [jnp.zeros((B, d_conv - 1, d_in), xs.dtype), xs],
            axis=1)[:, -(d_conv - 1):, :]
        h0 = jnp.zeros((B, d_in, d_state), jnp.float32)

    proj = xs_c @ p["x_proj"]
    dt = jax.nn.softplus(proj[..., :dt_rank] @ p["dt_proj"] + p["dt_bias"])
    Bs = proj[..., dt_rank:dt_rank + d_state]
    Cs = proj[..., dt_rank + d_state:]
    A = -jnp.exp(p["A_log"])

    y, hT = ssm_scan(xs_c, dt, Bs, Cs, A, p["D"], h0)
    out = (y * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": new_conv.astype(jnp.float32), "h": hT}


def mamba_state_init(cfg, batch):
    d_in, _, d_state, d_conv = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, d_conv - 1, d_in), jnp.float32),
        "h": jnp.zeros((batch, d_in, d_state), jnp.float32),
    }
