"""Common model primitives: norms, RoPE, SwiGLU MLP, initializers.

All modules are pure functions over parameter dicts; parameters for scanned
layer stacks are stacked on a leading layer axis by the model assembler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_dense(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def rms_norm(x, gamma, eps=1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def rope_freqs(d_rot: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(x, positions, theta: float):
    """x: (..., T, H, d) with d even; positions: (..., T) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., T, d/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu_init(key, d_model, d_ff, dtype, variant="swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "w_up": init_dense(k2, d_model, d_ff, dtype),
        "w_down": init_dense(k3, d_ff, d_model, dtype),
    }
    if variant == "swiglu":
        p["w_gate"] = init_dense(k1, d_model, d_ff, dtype)
    return p


def swiglu_apply(p, x):
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    else:                      # 2-matrix GELU MLP (GPTBigCode / granite)
        h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


def embed_init(key, vocab, d_model, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)


def unembed(x, w):  # w: (vocab, d) -> logits in fp32 (bf16 MXU accum f32)
    return jnp.einsum("btd,vd->btv", x, w,
                      preferred_element_type=jnp.float32)


def cross_entropy(logits, targets, mask=None):
    """Mean CE over (optionally masked) positions. logits fp32 (B,T,V).

    The gold logit is extracted with an iota-compare reduction rather than
    take_along_axis: a gather over a vocab-sharded logits tensor makes
    GSPMD all-gather the full (tokens, vocab) array, while the masked
    reduction stays sharded and fuses.
    """
    logz = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0),
                   axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
