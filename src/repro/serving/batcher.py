"""Continuous batching for decode serving.

A fixed pool of `n_slots` decode slots shares one jitted decode step
(static shapes: the cache is allocated once at `max_len`). Requests are
admitted into free slots as they arrive (prefill writes the slot's cache
region), every decode tick advances all live slots in lock-step with a
per-slot position vector, and finished slots (EOS or length budget) are
freed immediately for the next queued request — no batch drain barrier.

This is the node-level LC/DC hook for serving: `idle_fraction()` reports
how often the pool has no live slots, which is exactly the gating window
the ICI study's `idle_frac` models (EXPERIMENTS.md SSBeyond-paper).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.models import model as M


@dataclass
class Request:
    rid: int
    tokens: list                      # prompt token ids
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, cfg, params, *, n_slots: int = 4,
                 max_len: int = 128, eos_id: int | None = None):
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = M.init_cache(cfg, n_slots, max_len, dtype=cfg.dtype)
        self.pos = jnp.zeros((n_slots,), jnp.int32)
        self.last_tok = jnp.zeros((n_slots, 1), jnp.int32)
        self.slot_req: list[Request | None] = [None] * n_slots
        self.queue: list[Request] = []
        self.ticks = 0
        self.idle_ticks = 0

        self._decode = jax.jit(
            lambda p, c, t, po: M.decode_step(cfg, p, c, t, po))
        # single-request prefill (B=1), merged into the pooled cache
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, p, b))

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            toks = jnp.asarray(req.tokens, jnp.int32)[None, :]
            logits, pre_cache = self._prefill(self.params,
                                              {"tokens": toks})
            self._write_slot(s, pre_cache, len(req.tokens))
            nxt = int(jnp.argmax(logits[0]))
            req.out.append(nxt)
            self.slot_req[s] = req
            self.pos = self.pos.at[s].set(len(req.tokens))
            self.last_tok = self.last_tok.at[s, 0].set(nxt)

    def _write_slot(self, s: int, pre_cache, plen: int):
        """Copy a single-request prefill cache into slot s of the pool.

        Handles both flat leaves (batch at axis 0) and layer-stacked
        leaves (n_scan at axis 0, batch at axis 1); shorter prefill seq
        dims land at offset 0 of the slot's region.
        """
        def merge(pool, single):
            if single.ndim != pool.ndim:
                return pool
            for ax in (0, 1):
                if pool.ndim <= ax:
                    break
                if pool.shape[ax] == self.n_slots and \
                        single.shape[ax] == 1 and \
                        pool.shape[:ax] == single.shape[:ax]:
                    sl = jnp.take(single, 0, axis=ax)
                    dst = jnp.take(pool, s, axis=ax)
                    upd = jax.lax.dynamic_update_slice(
                        dst, sl.astype(pool.dtype), (0,) * dst.ndim)
                    if ax == 0:
                        return pool.at[s].set(upd)
                    return pool.at[:, s].set(upd)
            return pool
        self.cache = jax.tree.map(merge, self.cache, pre_cache)

    # -- decode loop --------------------------------------------------------
    def step(self):
        """One lock-step decode tick over all slots."""
        self._admit()
        self.ticks += 1
        live = [s for s in range(self.n_slots)
                if self.slot_req[s] is not None]
        if not live:
            self.idle_ticks += 1
            return 0
        logits, self.cache = self._decode(self.params, self.cache,
                                          self.last_tok, self.pos)
        nxt = jnp.argmax(logits, axis=-1)
        self.pos = self.pos + 1
        self.last_tok = nxt[:, None].astype(jnp.int32)
        emitted = 0
        for s in live:
            req = self.slot_req[s]
            tok = int(nxt[s])
            req.out.append(tok)
            emitted += 1
            length_done = len(req.out) >= req.max_new
            eos_done = self.eos_id is not None and tok == self.eos_id
            full = int(self.pos[s]) >= self.max_len - 1
            if length_done or eos_done or full:
                req.done = True
                self.slot_req[s] = None     # slot freed for the queue
        return emitted

    def run(self, max_ticks: int = 1000) -> list[Request]:
        finished: list[Request] = []
        seen = set()
        while self.ticks < max_ticks and \
                (self.queue or any(self.slot_req)):
            self.step()
        return finished

    def idle_fraction(self) -> float:
        return self.idle_ticks / max(self.ticks, 1)
