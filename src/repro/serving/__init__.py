from repro.serving.batcher import ContinuousBatcher, Request
