"""Fault-tolerant training loop.

Production behaviours, all exercised by tests/test_trainer.py on CPU:
  * checkpoint/restart: periodic async checkpoints; on (re)start the
    loop resumes from the latest step; the data pipeline is stateless
    in the step index so resume is bitwise-deterministic;
  * failure injection: `fail_at_step` raises mid-run (after the step
    executes, before its checkpoint) to simulate a node loss — the test
    restarts and verifies losses match an uninterrupted run;
  * straggler mitigation: per-step wall times feed an EWMA detector;
    steps slower than `straggler_factor` x EWMA are flagged and counted
    (in a multi-host deployment this signal triggers hot-spare swap /
    elastic shrink -- here it is surfaced in the metrics);
  * elastic restart: `Trainer.restore` takes the CURRENT mesh's
    shardings, so restarting on a different device count re-shards the
    same checkpoint (tests/test_elastic.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore)
from repro.data.pipeline import DataConfig, batch_at
from repro.models import model as model_lib
from repro.optim import make_optimizer
from repro.train.steps import make_train_step


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class TrainerConfig:
    ckpt_dir: str
    total_steps: int = 100
    ckpt_every: int = 20
    keep: int = 3
    log_every: int = 10
    peak_lr: float = 3e-4
    fail_at_step: int | None = None
    straggler_factor: float = 3.0
    seed: int = 0


@dataclass
class Trainer:
    cfg: object                  # ModelConfig
    tcfg: TrainerConfig
    data: DataConfig
    dist: object | None = None
    kernel_fns: dict | None = None
    metrics_log: list = field(default_factory=list)

    def __post_init__(self):
        self._step_fn = jax.jit(make_train_step(
            self.cfg, self.dist, self.kernel_fns,
            peak_lr=self.tcfg.peak_lr))
        self._ckpt = AsyncCheckpointer(self.tcfg.ckpt_dir,
                                       keep=self.tcfg.keep)

    # -- state ------------------------------------------------------------
    def init_state(self):
        params = model_lib.init_params(
            self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        opt_init, _ = make_optimizer(self.cfg)
        return {"params": params, "opt": opt_init(params)}

    def restore_or_init(self, shardings=None):
        start = latest_step(self.tcfg.ckpt_dir)
        state = self.init_state()
        if start is not None:
            state, start = restore(self.tcfg.ckpt_dir, state,
                                   shardings=shardings)
            return state, start
        return state, 0

    # -- loop -------------------------------------------------------------
    def run(self, state=None, start_step: int | None = None):
        if state is None:
            state, start_step = self.restore_or_init()
        start_step = start_step or 0
        ewma = None
        stragglers = 0
        for step in range(start_step, self.tcfg.total_steps):
            batch = batch_at(self.data, step)
            t0 = time.perf_counter()
            params, opt, metrics = self._step_fn(
                state["params"], state["opt"], batch,
                jnp.asarray(step, jnp.int32))
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.perf_counter() - t0
            state = {"params": params, "opt": opt}

            ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
            slow = dt > self.tcfg.straggler_factor * ewma
            stragglers += int(slow)
            metrics.update(step=step, step_time_s=dt, straggler=slow,
                           stragglers_total=stragglers)
            self.metrics_log.append(metrics)

            done = step + 1
            if done % self.tcfg.ckpt_every == 0 or \
                    done == self.tcfg.total_steps:
                self._ckpt.save_async(state, done)
            if self.tcfg.fail_at_step is not None and \
                    done == self.tcfg.fail_at_step:
                self._ckpt.wait()
                raise SimulatedFailure(f"injected failure at step {done}")
        self._ckpt.wait()
        return state

    def losses(self):
        return [m["loss"] for m in self.metrics_log]
