"""Jittable train / prefill / decode step factories.

``make_train_step`` closes over (cfg, dist, optimizer) and returns a pure
function (params, opt_state, batch, step) -> (params, opt_state, metrics);
the launcher jits it with the sharding specs from distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as model_lib
from repro.optim import make_optimizer
from repro.optim.schedule import cosine_warmup


def _constrain_batch(batch, dist):
    if dist is None or dist.mesh is None:
        return batch
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"

    def c(x):
        B = x.shape[0] if x.ndim else 0
        if x.ndim and B % dist.data_size == 0:
            return jax.lax.with_sharding_constraint(
                x, P(da, *([None] * (x.ndim - 1))))
        return x

    return jax.tree.map(c, batch)


def make_train_step(cfg, dist=None, kernel_fns=None, peak_lr=3e-4,
                    warmup=100):
    _, opt_update = make_optimizer(cfg)

    def train_step(params, opt_state, batch, step):
        batch = _constrain_batch(batch, dist)

        def loss_fn(p, b):
            loss, metrics = model_lib.train_loss(cfg, p, b, dist,
                                                 kernel_fns)
            return loss, metrics

        if cfg.microbatches > 1:
            k = cfg.microbatches
            mb = jax.tree.map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                batch)

            def acc_step(carry, b):
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, _constrain_batch(b, dist))
                carry = jax.tree.map(
                    lambda c, gi: (c.astype(jnp.float32)
                                   + gi.astype(jnp.float32) / k)
                    .astype(c.dtype), carry, g)
                return carry, (l, m)

            # accumulate in the param dtype: an f32 accumulator for a
            # 1T-param model costs 16 GB/device (sharded) -- bf16 halves
            # it at ~2 bits of accumulation precision (documented)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, p.dtype), params)
            grads, (losses, ms) = jax.lax.scan(acc_step, zeros, mb)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        lr = cosine_warmup(step, peak_lr=peak_lr, warmup=warmup)
        new_params, new_opt = opt_update(grads, opt_state, params, lr)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg, dist=None, kernel_fns=None):
    def prefill_step(params, batch):
        batch = _constrain_batch(batch, dist)
        return model_lib.prefill(cfg, params, batch, dist, kernel_fns)
    return prefill_step


def make_decode_step(cfg, dist=None, kernel_fns=None):
    def decode(params, cache, token, pos):
        return model_lib.decode_step(cfg, params, cache, token, pos, dist,
                                     kernel_fns)
    return decode


def serve_step(cfg, params, cache, token, pos, dist=None):
    """One new token against an existing KV cache (the ``decode_*`` /
    ``long_*`` dry-run entry point)."""
    return model_lib.decode_step(cfg, params, cache, token, pos, dist)
