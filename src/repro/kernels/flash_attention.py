"""Flash attention as a Pallas TPU kernel.

Grid (B, H, nq, nk); the kv axis is the innermost ("arbitrary") dimension
so the (m, l, acc) online-softmax state lives in VMEM scratch across kv
steps. Q/K/V stream through VMEM in (block_q x d) / (block_k x d) tiles;
the (T, S) score matrix never exists. Causal/sliding-window blocks that
are fully masked are skipped with pl.when (real savings on TPU; the
interpret-mode oracle path executes them as no-ops).

Block sizes default to 128 to match the MXU (128x128) systolic array.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            causal: bool, swa_window: int, block_q: int, block_k: int,
            scale: float):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    def compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale   # (bq, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)           # (bk, d)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq,bk)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                  (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if swa_window:
            mask &= (qpos - kpos) < swa_window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                               # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    if causal:
        # skip blocks strictly above the diagonal
        pl.when(k_start <= q_start + block_q - 1)(compute)
    elif swa_window:
        pl.when(jnp.logical_and(k_start <= q_start + block_q - 1,
                                q_start - (k_start + block_k - 1)
                                < swa_window))(compute)
    else:
        compute()

    @pl.when(ki == pl.num_programs(3) - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, swa_window=0,
                    block_q=128, block_k=128, interpret=True):
    """q: (B,T,H,dq), k/v: (B,S,H,dq)/(B,S,H,dv) -> (B,T,H,dv)."""
    B, T, H, dq = q.shape
    S, dv = k.shape[1], v.shape[-1]
    assert dq == v.shape[-1], "kernel assumes dq == dv (pad if MLA)"
    bq, bk = min(block_q, T), min(block_k, S)
    assert T % bq == 0 and S % bk == 0, (T, S, bq, bk)
    grid = (B, H, T // bq, S // bk)

    kern = functools.partial(
        _kernel, causal=causal, swa_window=swa_window,
        block_q=bq, block_k=bk, scale=dq ** -0.5)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, dq), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, bk, 1, dq), lambda b, h, i, j: (b, j, h, 0)),
            pl.BlockSpec((1, bk, 1, dv), lambda b, h, i, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, dv),
                               lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, H, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom
            pltpu.VMEM((bq, dv), jnp.float32),    # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
