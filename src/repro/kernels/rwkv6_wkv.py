"""RWKV-6 wkv recurrence as a chunked Pallas TPU kernel.

The sequential form (one (dh x dh) state update per token) starves the
MXU; the chunked form processes CHUNK tokens per grid step with two
matmuls plus rank-1 bookkeeping:

  within a chunk, with per-token log-decay lw_t = log(w_t) and inclusive
  cumsum L_t:
      r~_t = r_t * exp(L_{t-1})        (decay-adjusted queries)
      k~_s = k_s * exp(-L_s)           (decay-adjusted keys)
      scores = tril_strict(r~ @ k~^T) + diag((r*u*k).sum(-1))
      y = scores @ v + (r~ @ S)
      S' = exp(L_last) * S + (k~ * exp(L_last))^T @ v

  (exp(-L) stays in fp32 range because RWKV-6 decay w = exp(-exp(x))
  is bounded below ~exp(-e) per token and CHUNK = 16.)

Grid: (B, H, T / CHUNK) with the chunk axis sequential; the (dh x dh)
state lives in VMEM scratch across chunk steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

CHUNK = 16


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
            s_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, :, 0, :].astype(jnp.float32)        # (ct, dh)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)
    u = u_ref[0, :].astype(jnp.float32)              # (dh,)

    lw = jnp.log(jnp.maximum(w, 1e-38))              # (ct, dh) negative
    L = jnp.cumsum(lw, axis=0)                       # inclusive
    L_prev = L - lw                                  # exclusive
    r_t = r * jnp.exp(L_prev)
    k_t = k * jnp.exp(-L)

    S = s_ref[...]                                   # (dh, dh)
    y_inter = jax.lax.dot_general(r_t, S, (((1,), (0,)), ((), ())))

    scores = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())))
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ti > si, scores, 0.0)         # strict lower
    diag = jnp.sum(r * u[None, :] * k, axis=1)       # bonus u on the diag
    y_intra = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ()))) \
        + diag[:, None] * v

    y_ref[0, :, 0, :] = (y_inter + y_intra).astype(y_ref.dtype)

    decay_all = jnp.exp(L[-1, :])                    # (dh,)
    kv = jax.lax.dot_general(k_t * decay_all[None, :], v,
                             (((0,), (0,)), ((), ())))
    s_ref[...] = decay_all[:, None] * S + kv

    @pl.when(ci == pl.num_programs(2) - 1)
    def _finish():
        sT_ref[0, 0] = s_ref[...]


def wkv_chunked(r, k, v, w, u, state, *, chunk=CHUNK, interpret=True):
    """r,k,v,w: (B,T,H,dh); u: (H,dh); state: (B,H,dh,dh) fp32.
    Returns (y (B,T,H,dh), final state)."""
    B, T, H, dh = r.shape
    ct = min(chunk, T)
    assert T % ct == 0, (T, ct)
    grid = (B, H, T // ct)
    kern = functools.partial(_kernel, chunk=ct)

    y, sT = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, ct, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, ct, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, ct, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, ct, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, dh), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ct, 1, dh), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, dh, dh), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, H, dh), r.dtype),
            jax.ShapeDtypeStruct((B, H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u, state.astype(jnp.float32))
    return y, sT
