"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

* attention_ref     - reuses the model's chunked online-softmax attention
                      (repro/models/attention.py), itself validated against
                      a naive softmax in the tests.
* attention_naive   - O(T*S) direct softmax (small shapes only).
* wkv_ref           - sequential RWKV-6 recurrence (repro/models/rwkv6.py).
* switch_step_ref   - one LC/DC switch tick, identical semantics to
                      kernels/lcdc_switch.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import chunked_attention as attention_ref  # noqa
from repro.models.rwkv6 import wkv_scan as wkv_ref  # noqa

BIG = 1e30


def attention_naive(q, k, v, *, causal=True, swa_window=0):
    B, T, H, dq = q.shape
    S = k.shape[1]
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dq ** -0.5
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qp >= kp
    if swa_window:
        mask &= (qp - kp) < swa_window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def switch_step_ref(queues, stage, arrivals, *, cap=20.0, hi=0.75, lo=0.22):
    S, L = queues.shape
    idx = jnp.arange(L)[None, :]
    act = idx < stage[:, None]
    masked = jnp.where(act, queues, BIG)
    mn = jnp.min(masked, axis=1, keepdims=True)
    pick = masked == mn
    pick &= jnp.cumsum(pick.astype(jnp.int32), axis=1) == 1
    room = jnp.maximum(cap - mn[:, 0], 0.0)
    add = jnp.minimum(arrivals, room)
    dropped = arrivals - add
    q = queues + pick.astype(queues.dtype) * add[:, None]
    q = jnp.maximum(q - act.astype(q.dtype), 0.0)
    hi_t = jnp.any((q > hi * cap) & act, axis=1).astype(jnp.int32)
    lo_t = jnp.all(jnp.where(act, q < lo * cap, True), axis=1) \
        .astype(jnp.int32)
    return q, hi_t, lo_t, dropped
