"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

* attention_ref     - reuses the model's chunked online-softmax attention
                      (repro/models/attention.py), itself validated against
                      a naive softmax in the tests.
* attention_naive   - O(T*S) direct softmax (small shapes only).
* wkv_ref           - sequential RWKV-6 recurrence (repro/models/rwkv6.py).
* switch_step_ref   - one LC/DC switch tick, identical semantics to
                      kernels/lcdc_switch.py. This is THE shared
                      semantic definition of the per-switch datapath:
                      the simulator hot loop routes through it (via
                      ops.switch_step) on CPU, and the Pallas kernel is
                      validated against it, so min-backlog pick /
                      capacity clamp / serve / watermark logic lives in
                      exactly one jnp implementation (usable-link and
                      watermark predicates are imported from
                      core/gating.py, the controller's own definitions).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import gating
from repro.models.attention import chunked_attention as attention_ref  # noqa
from repro.models.rwkv6 import wkv_scan as wkv_ref  # noqa

BIG = 1e30


def attention_naive(q, k, v, *, causal=True, swa_window=0):
    B, T, H, dq = q.shape
    S = k.shape[1]
    s = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * dq ** -0.5
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qp >= kp
    if swa_window:
        mask &= (qp - kp) < swa_window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhts,bshd->bthd", p, v.astype(jnp.float32))
    return out.astype(v.dtype)


def switch_step_ref(queues, stage, arrivals, draining=None, *,
                    valid=None, cap=20.0, hi=0.75, lo=0.22,
                    serve_rate=1.0):
    """One switch tick for a tier of S switches with L output ports.

    queues:   (S, L, K) per-port backlogs split into K traffic
              components (e.g. K=2 for the RSW's [intra, inter] split),
              or (S, L) for the K=1 shorthand.
    stage:    (S,) int32 active-stage counts (ports [0, stage) enabled).
    arrivals: (S, K) — or (S,) with 2-D queues — per-switch arrival
              vector enqueued onto the min-backlog usable port.
    draining: (S,) bool; a draining top port serves but does not accept.
    valid:    (S,) bool padding mask for heterogeneous-site batches, or
              (S, L) bool per-LINK usability mask (the fault-injection
              axis: a hard-faulted transceiver is a dead port on an
              otherwise live switch — it accepts nothing and serves
              nothing, while the switch's healthy ports keep working).
              A switch with no valid port at all is inert: it accepts
              nothing, serves nothing, raises no triggers, and its
              queues pass through unchanged — but any arrival fed to it
              IS counted as a drop (a whole-switch fault outage must
              not silently lose packets). Padded switches stay
              drop-free because callers feed them zero arrivals;
              arrivals at a live switch whose usable ports are all
              dead are counted as drops too.

    Semantics per switch: (1) pick the usable port with the least total
    backlog, (2) enqueue the arrival vector there, proportionally scaled
    so the port total never exceeds ``cap`` (the clipped excess is
    dropped), (3) serve up to ``serve_rate`` pkts/tick per active port,
    split proportionally across the K components, (4) raise hi/lo
    watermark triggers on the post-serve backlogs, (5) emit the
    backlog-age / occupancy moments that feed the simulator's in-scan
    delay histograms.

    Returns (new_queues, served, hi_trig, lo_trig, dropped, enq_wait,
    occ_m1, occ_m2) where served has the queues' shape, hi/lo are int32
    (S,), dropped is (S,), and the moment outputs are (S,) float:

    enq_wait: the queue wait a packet arriving THIS tick inherits — the
              pre-enqueue backlog of the min-backlog pick divided by
              ``serve_rate`` (ticks until head-of-line). 0 for invalid
              switches.
    occ_m1:   sum over the switch's output ports of the post-serve
              per-port backlog (first occupancy moment).
    occ_m2:   sum of the squared post-serve per-port backlogs (second
              moment; m2/n - (m1/n)^2 is the backlog variance over
              port-ticks). Both 0 for invalid switches.
    """
    squeeze = queues.ndim == 2
    if squeeze:
        queues = queues[..., None]
        arrivals = arrivals[..., None]
    S, L, K = queues.shape
    if draining is None:
        draining = jnp.zeros((S,), bool)
    if valid is None:
        valid = jnp.ones((S,), bool)
    # (S,) per-switch padding mask or (S, L) per-link fault/usability
    # mask — broadcast to per-link; a switch is live iff any port is
    link_valid = valid[:, None] if valid.ndim == 1 \
        else jnp.asarray(valid, bool)
    vswitch = jnp.any(link_valid, axis=1)               # (S,)

    act = (jnp.arange(L)[None, :] < stage[:, None]) & link_valid
    usable = gating.usable_links(stage, draining, L) & link_valid
    qtot = jnp.sum(queues, axis=2)                      # (S, L)

    # (1) min-backlog usable port, ties to the lowest index
    masked = jnp.where(usable, qtot, BIG)
    mn = jnp.min(masked, axis=1, keepdims=True)
    pick = masked == mn
    pick &= jnp.cumsum(pick.astype(jnp.int32), axis=1) == 1
    # per-link faults can leave a live switch with NO usable port this
    # tick (its pick row is all-False): guard the BIG sentinel out of
    # the taps and drop the whole arrival below (room collapses to 0)
    has_usable = jnp.any(usable, axis=1)
    mn0 = jnp.where(has_usable, mn[:, 0], 0.0)

    # (5a) backlog-age of the pick: what an arrival queues behind
    enq_wait = jnp.where(vswitch, mn0, 0.0) / serve_rate

    # (2) enqueue with capacity clamp (proportional over components);
    # an arrival at a switch with NO valid port left (every transceiver
    # hard-faulted) is a counted drop, not a silent loss — packet
    # conservation must survive whole-switch fault outages. Padded
    # (invalid) switches still report 0: they receive zero arrivals.
    add_tot = jnp.sum(arrivals, axis=1)                 # (S,)
    room = jnp.where(has_usable,
                     jnp.maximum(cap - mn0, 0.0), 0.0)
    scale = jnp.minimum(1.0, room / jnp.maximum(add_tot, 1e-9))
    dropped = jnp.where(vswitch, add_tot * (1.0 - scale), add_tot)
    q = queues + pick.astype(queues.dtype)[..., None] \
        * (arrivals * scale[:, None])[:, None, :]

    # (3) serve up to serve_rate pkts per active port, proportional
    # across components (a draining top port keeps draining: it is
    # active until the drain completes and the stage drops)
    qtot = jnp.sum(q, axis=2)
    serve_tot = jnp.minimum(qtot, serve_rate) * act
    frac = serve_tot / jnp.maximum(qtot, 1e-9)
    served = q * frac[..., None]
    q = q - served

    # (5b) post-serve occupancy moments over the switch's output ports
    qpost = qtot - serve_tot
    occ_m1 = jnp.where(vswitch, jnp.sum(qpost, axis=1), 0.0)
    occ_m2 = jnp.where(vswitch, jnp.sum(qpost * qpost, axis=1), 0.0)

    # (4) watermark triggers on post-serve backlogs (shared definition,
    # restricted to the valid/healthy ports); invalid switches never
    # trigger
    hi_t, lo_t = gating.watermark_triggers(qpost, stage, cap=cap, hi=hi,
                                           lo=lo, link_valid=link_valid)
    hi_t, lo_t = hi_t & vswitch, lo_t & vswitch
    if squeeze:
        q, served = q[..., 0], served[..., 0]
    return (q, served, hi_t.astype(jnp.int32), lo_t.astype(jnp.int32),
            dropped, enq_wait, occ_m1, occ_m2)
