"""Jitted dispatch wrappers for the Pallas kernels.

On TPU the Pallas body compiles natively; on CPU (this container) the
default is the pure-jnp reference path so jitted model code stays
analyzable/compilable, with ``use_pallas=True`` running the kernels in
interpret mode (the correctness path exercised by tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import lcdc_switch as _sw
from repro.kernels import rwkv6_wkv as _wkv
from repro.kernels import ref as _ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal=True, swa_window=0, use_pallas=None,
              block_q=128, block_k=128):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _fa.flash_attention(q, k, v, causal=causal,
                                   swa_window=swa_window, block_q=block_q,
                                   block_k=block_k,
                                   interpret=not _on_tpu())
    return _ref.attention_ref(q, k, v, causal=causal, swa_window=swa_window)


def wkv(r, k, v, w, u, state, *, use_pallas=None, chunk=16):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _wkv.wkv_chunked(r, k, v, w, u, state, chunk=chunk,
                                interpret=not _on_tpu())
    return _ref.wkv_ref(r, k, v, w, u, state)


def switch_step(queues, stage, arrivals, draining=None, *, valid=None,
                cap=20.0, hi=0.75, lo=0.22, serve_rate=1.0,
                use_pallas=None):
    """One LC/DC switch tick (the simulator's production datapath).

    Pallas on TPU, pure-jnp reference on CPU — identical semantics
    (tests/test_kernels.py pins the kernel to the oracle). See
    ref.switch_step_ref for the argument/return contract; queues may be
    (S, L, K) component-split or plain (S, L). ``valid`` is either the
    (S,) padding mask of heterogeneous-site batches (invalid switches
    are inert) or an (S, L) per-LINK usability mask — the
    fault-injection axis: a hard-faulted transceiver is a dead port on
    an otherwise live switch. Besides the datapath outputs, both paths
    emit the per-switch
    backlog-age (``enq_wait``: what an arrival queues behind, in ticks)
    and post-serve occupancy moments (``occ_m1``/``occ_m2``) that feed
    the simulator's in-scan delay histograms, so the distribution
    subsystem runs off the same oracle-checked kernel."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return _sw.switch_step(queues, stage, arrivals, draining,
                               valid=valid, cap=cap, hi=hi, lo=lo,
                               serve_rate=serve_rate,
                               interpret=not _on_tpu())
    return _ref.switch_step_ref(queues, stage, arrivals, draining,
                                valid=valid, cap=cap, hi=hi, lo=lo,
                                serve_rate=serve_rate)


def model_kernel_fns(use_pallas: bool = True) -> dict:
    """kernel_fns dict for repro.models.model entry points."""
    return {
        "attention": functools.partial(attention, use_pallas=use_pallas),
        "wkv": lambda r, k, v, w, u, s: wkv(r, k, v, w, u, s,
                                            use_pallas=use_pallas),
    }
