"""LC/DC switch datapath step as a Pallas kernel.

The TPU-native analogue of the paper's FPGA pipeline (Sec III-B): for a
tile of switches, one tick of
  (1) min-backlog output-queue selection over the stage-enabled ports
      (the per-stage CAM lookup + weighted scheduler), honouring a
      draining top port that serves but no longer accepts traffic,
  (2) arrival enqueue with capacity clamp (drop counting) — arrivals are
      a per-switch vector of K traffic components (the simulator's
      [intra, inter] split), enqueued proportionally,
  (3) up-to-serve_rate pkt/port service over active ports, split
      proportionally across the K components,
  (4) high/low watermark trigger generation (the backlog monitor),
  (5) backlog-age / occupancy-moment taps: the pre-enqueue backlog the
      arriving packet queues behind (in ticks-to-serve) plus the first
      and second post-serve occupancy moments over the output ports —
      the oracle-checked feed of the simulator's in-scan packet-delay
      histograms.

All switches in a tile advance in one VPU-wide vector step; queues are
laid out (S, L*K) so the tile stays 2-D (lane-friendly) and is reshaped
to (bs, L, K) inside the kernel. cap/hi/lo ride in as per-switch operand
columns rather than compile-time constants so per-scenario values (the
batched sweep engine's array-valued knobs) trace through one compile.

The switch axis is padded up to the block size and outputs sliced back,
so odd-sized tiers (e.g. the 16-CSW tier under a 128 block) work.

The sim's pure-jnp path (ref.switch_step_ref) is the oracle and the CPU
execution path; on TPU ops.switch_step dispatches here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _kernel(q_ref, stage_ref, drain_ref, valid_ref, arr_ref, cap_ref,
            hi_ref, lo_ref, qo_ref, srv_ref, hi_o_ref, lo_o_ref,
            drop_ref, wait_ref, m1_ref, m2_ref, *, n_links: int,
            n_comp: int, serve_rate: float):
    L, K = n_links, n_comp
    bs = q_ref.shape[0]
    q = q_ref[...].reshape(bs, L, K)
    stage = stage_ref[...]                          # (bs, 1) int32
    drain = drain_ref[...] != 0                     # (bs, 1)
    link_valid = valid_ref[...] != 0                # (bs, L) per-link
    arr = arr_ref[...]                              # (bs, K)
    cap = cap_ref[...]                              # (bs, 1)
    # a switch is live iff any of its ports is valid
    vswitch = jnp.any(link_valid, axis=1, keepdims=True)   # (bs, 1)

    idx = jax.lax.broadcasted_iota(jnp.int32, (bs, L), 1)
    act = (idx < stage) & link_valid
    top = idx == stage - 1
    usable = act & ~(drain & top & (stage > 1))
    qtot = jnp.sum(q, axis=2)                       # (bs, L)

    # (1) min-backlog selection among usable ports, ties to lowest index
    masked = jnp.where(usable, qtot, BIG)
    mn = jnp.min(masked, axis=1, keepdims=True)
    pick = masked == mn
    pick &= jnp.cumsum(pick.astype(jnp.int32), axis=1) == 1
    # per-link faults can leave a live switch with no usable port: keep
    # the BIG sentinel out of the taps and collapse the room to 0 so
    # the whole arrival drops (matches ref.switch_step_ref)
    has_usable = jnp.any(usable, axis=1, keepdims=True)
    mn0 = jnp.where(has_usable, mn, 0.0)

    # (5a) backlog-age of the pick: what an arrival queues behind
    wait_ref[...] = jnp.where(vswitch, mn0, 0.0) / serve_rate

    # (2) enqueue with capacity clamp, proportional over components; an
    # arrival at a switch with no valid port left (all transceivers
    # hard-faulted) is a counted drop, not a silent loss (padded
    # switches receive zero arrivals, so they still report 0)
    add_tot = jnp.sum(arr, axis=1, keepdims=True)   # (bs, 1)
    room = jnp.where(has_usable, jnp.maximum(cap - mn0, 0.0), 0.0)
    scale = jnp.minimum(1.0, room / jnp.maximum(add_tot, 1e-9))
    drop_ref[...] = jnp.where(vswitch, add_tot * (1.0 - scale), add_tot)
    q = q + pick.astype(q.dtype)[..., None] \
        * (arr * scale)[:, None, :]

    # (3) serve up to serve_rate pkts per active port, proportional
    qtot = jnp.sum(q, axis=2)
    serve_tot = jnp.minimum(qtot, serve_rate) * act
    frac = serve_tot / jnp.maximum(qtot, 1e-9)
    served = q * frac[..., None]
    q = q - served
    qo_ref[...] = q.reshape(bs, L * K)
    srv_ref[...] = served.reshape(bs, L * K)

    # (4) watermark triggers on post-serve backlogs; invalid switches
    # never trigger (lo would otherwise fire vacuously on act==empty)
    qpost = qtot - serve_tot

    # (5b) post-serve occupancy moments over the output ports
    m1_ref[...] = jnp.where(vswitch,
                            jnp.sum(qpost, axis=1, keepdims=True), 0.0)
    m2_ref[...] = jnp.where(vswitch,
                            jnp.sum(qpost * qpost, axis=1, keepdims=True),
                            0.0)
    hi_o_ref[...] = jnp.any((qpost > hi_ref[...] * cap) & act, axis=1,
                            keepdims=True).astype(jnp.int32)
    lo_o_ref[...] = (jnp.all(jnp.where(act, qpost < lo_ref[...] * cap,
                                       True), axis=1, keepdims=True)
                     & vswitch).astype(jnp.int32)


def switch_step(queues, stage, arrivals, draining=None, *, valid=None,
                cap=20.0, hi=0.75, lo=0.22, serve_rate=1.0, block_s=128,
                interpret=True):
    """queues (S, L, K) or (S, L); stage (S,) int32; arrivals (S, K) or
    (S,); draining (S,) bool; valid (S,) bool padding mask (invalid
    switches are inert) or (S, L) bool per-link usability mask (the
    fault-injection axis: dead ports on live switches). Same contract
    as ref.switch_step_ref: returns (new_queues, served, hi_trig,
    lo_trig, dropped, enq_wait, occ_m1, occ_m2)."""
    squeeze = queues.ndim == 2
    if squeeze:
        queues = queues[..., None]
        arrivals = arrivals[..., None]
    S, L, K = queues.shape
    if draining is None:
        draining = jnp.zeros((S,), bool)
    if valid is None:
        valid = jnp.ones((S,), bool)
    # per-switch masks broadcast to the kernel's per-link operand
    link_valid = jnp.broadcast_to(valid[:, None], (S, L)) \
        if valid.ndim == 1 else jnp.asarray(valid, bool)

    # pad the switch axis to the block size (idle switches: stage 1,
    # empty queues, zero arrivals, valid=0) and slice the outputs back
    bs = min(block_s, _round_up(S, 8))
    Sp = _round_up(S, bs)
    pad = Sp - S
    f32 = queues.dtype
    qp = jnp.pad(queues, ((0, pad), (0, 0), (0, 0))).reshape(Sp, L * K)
    stage_p = jnp.pad(stage, (0, pad), constant_values=1)[:, None]
    drain_p = jnp.pad(draining, (0, pad)).astype(jnp.int32)[:, None]
    valid_p = jnp.pad(link_valid, ((0, pad), (0, 0))).astype(jnp.int32)
    arr_p = jnp.pad(arrivals, ((0, pad), (0, 0)))
    def col(v):
        # scalar or per-switch (S,) knob -> padded (Sp, 1) operand column
        v = jnp.asarray(v, f32)
        if v.ndim == 0:
            return jnp.full((Sp, 1), v)
        return jnp.pad(v.reshape(-1), (0, pad))[:, None]

    kern = functools.partial(_kernel, n_links=L, n_comp=K,
                             serve_rate=float(serve_rate))
    spec_lk = pl.BlockSpec((bs, L * K), lambda i: (i, 0))
    spec_1 = pl.BlockSpec((bs, 1), lambda i: (i, 0))
    spec_k = pl.BlockSpec((bs, K), lambda i: (i, 0))
    spec_l = pl.BlockSpec((bs, L), lambda i: (i, 0))
    qo, srv, hi_t, lo_t, drop, wait, m1, m2 = pl.pallas_call(
        kern,
        grid=(Sp // bs,),
        in_specs=[spec_lk, spec_1, spec_1, spec_l, spec_k, spec_1, spec_1,
                  spec_1],
        out_specs=[spec_lk, spec_lk, spec_1, spec_1, spec_1, spec_1,
                   spec_1, spec_1],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, L * K), f32),
            jax.ShapeDtypeStruct((Sp, L * K), f32),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Sp, 1), jnp.int32),
            jax.ShapeDtypeStruct((Sp, 1), f32),
            jax.ShapeDtypeStruct((Sp, 1), f32),
            jax.ShapeDtypeStruct((Sp, 1), f32),
            jax.ShapeDtypeStruct((Sp, 1), f32),
        ],
        interpret=interpret,
    )(qp, stage_p, drain_p, valid_p, arr_p, col(cap), col(hi), col(lo))
    qo = qo[:S].reshape(S, L, K)
    srv = srv[:S].reshape(S, L, K)
    if squeeze:
        qo, srv = qo[..., 0], srv[..., 0]
    return (qo, srv, hi_t[:S, 0], lo_t[:S, 0], drop[:S, 0], wait[:S, 0],
            m1[:S, 0], m2[:S, 0])


def _round_up(n: int, m: int) -> int:
    return (n + m - 1) // m * m
