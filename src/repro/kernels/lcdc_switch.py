"""LC/DC switch datapath step as a Pallas kernel.

The TPU-native analogue of the paper's FPGA pipeline (Sec III-B): for a
tile of switches, one tick of
  (1) min-backlog output-queue selection over the stage-enabled ports
      (the per-stage CAM lookup + weighted scheduler),
  (2) arrival enqueue with capacity clamp (drop counting),
  (3) 1-pkt/port service over enabled ports,
  (4) high/low watermark trigger generation (the backlog monitor).

All switches in a tile advance in one VPU-wide vector step; the sim's
pure-jnp path (ref.switch_step) is the oracle and the CPU execution
path; on TPU ops.switch_step dispatches here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 1e30


def _kernel(q_ref, stage_ref, arr_ref, qo_ref, hi_ref, lo_ref, drop_ref, *,
            cap: float, hi: float, lo: float, n_links: int):
    q = q_ref[...]                                  # (bs, L)
    stage = stage_ref[...]                          # (bs, 1) int32
    arr = arr_ref[...]                              # (bs, 1)

    idx = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1)
    act = idx < stage

    # (1) min-backlog selection among active ports
    masked = jnp.where(act, q, BIG)
    mn = jnp.min(masked, axis=1, keepdims=True)
    pick = (masked == mn)
    # break ties toward the lowest index
    first = jnp.cumsum(pick.astype(jnp.int32), axis=1) == 1
    pick &= first

    # (2) enqueue with capacity clamp
    room = jnp.maximum(cap - mn, 0.0)
    add = jnp.minimum(arr, room)
    drop_ref[...] = arr - add
    q = q + pick.astype(q.dtype) * add

    # (3) serve one packet per active port
    q = jnp.maximum(q - act.astype(q.dtype), 0.0)
    qo_ref[...] = q

    # (4) watermark triggers
    hi_ref[...] = jnp.any((q > hi * cap) & act, axis=1,
                          keepdims=True).astype(jnp.int32)
    lo_ref[...] = jnp.all(jnp.where(act, q < lo * cap, True), axis=1,
                          keepdims=True).astype(jnp.int32)


def switch_step(queues, stage, arrivals, *, cap=20.0, hi=0.75, lo=0.22,
                block_s=128, interpret=True):
    """queues: (S, L) f32; stage: (S,) int32; arrivals: (S,) f32.
    Returns (new_queues, hi_trig (S,), lo_trig (S,), dropped (S,))."""
    S, L = queues.shape
    bs = min(block_s, S)
    assert S % bs == 0
    kern = functools.partial(_kernel, cap=float(cap), hi=float(hi),
                             lo=float(lo), n_links=L)
    qo, hi_t, lo_t, drop = pl.pallas_call(
        kern,
        grid=(S // bs,),
        in_specs=[
            pl.BlockSpec((bs, L), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bs, L), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
            pl.BlockSpec((bs, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((S, L), queues.dtype),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
            jax.ShapeDtypeStruct((S, 1), jnp.int32),
            jax.ShapeDtypeStruct((S, 1), queues.dtype),
        ],
        interpret=interpret,
    )(queues, stage[:, None], arrivals[:, None])
    return qo, hi_t[:, 0], lo_t[:, 0], drop[:, 0]
