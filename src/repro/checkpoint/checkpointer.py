"""Sharded, atomic, async checkpointing (no external deps).

Layout:  <dir>/step_<N>/
            manifest.json       (tree structure, shapes, dtypes, step)
            shard_<i>.npz       (leaf arrays, chunked ~512 MB per shard)
         <dir>/step_<N>.tmp...  (written first, atomically renamed)

Properties needed at fleet scale and tested in tests/test_checkpoint.py:
  * atomic: a crash mid-save never corrupts the latest checkpoint
    (tmp-dir + os.replace rename);
  * async: `save_async` snapshots to host RAM (jax.device_get) and writes
    on a background thread so the train loop keeps stepping;
  * keep-k retention;
  * elastic restore: leaves are stored unsharded, so a restore onto a
    different mesh/device-count just re-shards via the caller's
    in_shardings (tests restore a 4-way-trained state onto a 2-way mesh).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np

SHARD_BYTES = 512 * 2 ** 20


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str | Path, tree, step: int, keep: int = 3) -> Path:
    """Synchronous atomic save. Returns the final checkpoint dir."""
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    final = path / f"step_{step:08d}"
    tmp = path / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(x)) for x in leaves]

    shards: list[list[int]] = [[]]
    size = 0
    for i, a in enumerate(host):
        if size > SHARD_BYTES:
            shards.append([])
            size = 0
        shards[-1].append(i)
        size += a.nbytes
    for si, idxs in enumerate(shards):
        np.savez(tmp / f"shard_{si}.npz",
                 **{f"leaf_{i}": host[i] for i in idxs})
    manifest = {
        "step": step,
        "n_leaves": len(host),
        "treedef": str(treedef),
        "shards": {str(si): idxs for si, idxs in enumerate(shards)},
        "shapes": [list(a.shape) for a in host],
        "dtypes": [str(a.dtype) for a in host],
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                       # atomic publish
    _retain(path, keep)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write on a daemon thread."""

    def __init__(self, path: str | Path, keep: int = 3):
        self.path = Path(path)
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save_async(self, tree, step: int):
        self.wait()
        # snapshot NOW (device_get) so later param donation can't race
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        snap = jax.tree_util.tree_unflatten(treedef, host)
        self._thread = threading.Thread(
            target=save, args=(self.path, snap, step, self.keep),
            daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _retain(path: Path, keep: int):
    ckpts = sorted(p for p in path.iterdir()
                   if p.is_dir() and p.name.startswith("step_"))
    for old in ckpts[:-keep]:
        shutil.rmtree(old)


def latest_step(path: str | Path) -> int | None:
    path = Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(path: str | Path, tree_like, step: int | None = None,
            shardings=None):
    """Restore into the structure of `tree_like`. With `shardings`
    (a matching pytree of jax.sharding.Sharding) leaves go straight to
    devices with the new layout — this is the elastic-reshard path."""
    path = Path(path)
    step = step if step is not None else latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {path}")
    d = path / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    host = [None] * manifest["n_leaves"]
    for si, idxs in manifest["shards"].items():
        with np.load(d / f"shard_{si}.npz") as z:
            for i in idxs:
                host[i] = z[f"leaf_{i}"]
    leaves, treedef = _flatten(tree_like)
    assert len(leaves) == len(host), \
        f"checkpoint has {len(host)} leaves, target {len(leaves)}"
    if shardings is not None:
        sh_leaves = treedef.flatten_up_to(shardings)
        host = [jax.device_put(h, s) for h, s in zip(host, sh_leaves)]
    else:
        host = [jax.numpy.asarray(h) for h in host]
    return jax.tree_util.tree_unflatten(treedef, host), step
