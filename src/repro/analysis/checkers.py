"""Non-taint checkers: RL003 (host-side transfer smells in hot
modules), RL005 (PRNG key discipline), RL006 (dtype discipline).

RL003 has two halves. The taint engine (taint.py) catches transfers of
*traced* values inside traced functions; this module catches the
host-side half — ``jax.device_get`` / ``.block_until_ready()`` anywhere
in a hot-loop module outside the blessed fetch points declared in
compile_sites.toml. The blessed points are the contract: exactly the
fetches the HOST_TRANSFER_COUNT pin counts.

RL005 walks every function linearly, tracking PRNG-key names: a name
assigned from ``jax.random.PRNGKey/split/fold_in`` is *fresh*; a
sampling call consumes it; a second sampling call on a consumed name
without re-derivation is the finding. Passing a key into an opaque
call marks it consumed (the callee may sample) but is not itself a
finding. Loop bodies run twice so a key consumed across iterations is
caught.

RL006 flags float64 dtypes — ``np.float64`` / ``jnp.float64`` /
``np.double`` attributes, ``dtype="float64"`` / ``dtype=float`` /
``.astype("float64")`` — in the bit-exact modules (kernels, gating):
results there must be identical whether or not x64 is enabled, so any
float64 request is either dead (x64 off) or a parity break (x64 on).
"""
from __future__ import annotations

import ast
import re

from .astutil import ModuleIndex, dotted_name, resolves_to
from .findings import Finding

#: parameter names treated as PRNG keys on function entry (a key that
#: *arrives* as an argument is fresh; reuse inside the body is still
#: reuse even though the derivation happened in the caller)
_KEYISH = re.compile(r"(^|_)(key|keys|rng|prng)(_|$)", re.IGNORECASE)

_SAMPLERS = (
    "uniform", "normal", "bernoulli", "randint", "choice",
    "permutation", "categorical", "gamma", "beta", "exponential",
    "truncated_normal", "gumbel", "laplace", "poisson", "bits",
    "rademacher", "dirichlet", "multivariate_normal", "t", "cauchy",
    "loggamma", "logistic", "maxwell", "orthogonal", "rayleigh",
    "weibull_min", "ball", "binomial", "chisquare", "f", "geometric",
    "generalized_normal", "pareto", "triangular", "wald",
)
_DERIVERS = ("split", "fold_in", "PRNGKey", "key", "clone",
             "wrap_key_data", "key_data")
_SAMPLER_DOTTED = tuple(f"jax.random.{s}" for s in _SAMPLERS)
_DERIVER_DOTTED = tuple(f"jax.random.{d}" for d in _DERIVERS)

_F64_ATTRS = ("numpy.float64", "numpy.double", "numpy.longdouble",
              "jax.numpy.float64", "numpy.complex128",
              "jax.numpy.complex128")
_F64_STRINGS = {"float64", "f8", "double", "complex128"}


# ---------------------------------------------------------------------------
# RL003 — host-side transfer smells in hot modules
# ---------------------------------------------------------------------------

def check_host_transfers(mi: ModuleIndex, blessed: set) -> list:
    """``jax.device_get`` / ``.block_until_ready()`` outside blessed
    qualnames. ``blessed`` is a set of function qualnames for this file
    (a finding inside a blessed function, or nested under one, is the
    declared fetch point itself)."""
    out = []

    def bless_covers(node) -> bool:
        for fi in mi.funcs.values():
            fn = fi.node
            if (fn.lineno <= node.lineno
                    <= getattr(fn, "end_lineno", fn.lineno)):
                q = fi.qualname
                if q in blessed or any(q.startswith(b + ".")
                                       for b in blessed):
                    return True
        return False

    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call):
            continue
        if resolves_to(mi, node.func, "jax.device_get"):
            if not bless_covers(node):
                out.append(Finding(
                    "RL003", mi.path, node.lineno,
                    "jax.device_get outside the blessed fetch points "
                    "(declare it in compile_sites.toml "
                    "[[blessed_transfer]] or route through the sweep "
                    "fold fetch)"))
        elif (isinstance(node.func, ast.Attribute)
              and node.func.attr == "block_until_ready"):
            if not bless_covers(node):
                out.append(Finding(
                    "RL003", mi.path, node.lineno,
                    ".block_until_ready() is a host sync barrier in a "
                    "hot-loop module (bless it or move it to the "
                    "benchmark harness)"))
    return out


# ---------------------------------------------------------------------------
# RL005 — PRNG key discipline
# ---------------------------------------------------------------------------

class _KeyWalk:
    def __init__(self, mi: ModuleIndex, path: str):
        self.mi = mi
        self.path = path
        self.state: dict = {}        # name -> "fresh" | "consumed"
        self.findings: list = []

    def _is(self, call: ast.Call, dotted: tuple) -> bool:
        return resolves_to(self.mi, call.func, *dotted)

    def _key_args(self, call: ast.Call):
        names = []
        for a in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(a, ast.Name) and a.id in self.state:
                names.append(a.id)
        return names

    # -- expression scan (in evaluation-ish order) -----------------------
    def expr(self, e):
        if e is None:
            return
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr) and not isinstance(
                    e, ast.Call):
                self.expr(child)
        if isinstance(e, ast.Call):
            for a in e.args:
                self.expr(a)
            for kw in e.keywords:
                self.expr(kw.value)
            if self._is(e, _SAMPLER_DOTTED):
                keys = self._key_args(e)
                for k in keys[:1]:   # first key-typed arg is the key
                    if self.state.get(k) == "consumed":
                        self.findings.append(Finding(
                            "RL005", self.path, e.lineno,
                            f"PRNG key {k!r} feeds a second sampling "
                            "call without an intervening split/"
                            "fold_in"))
                    else:
                        self.state[k] = "consumed"
            elif self._is(e, _DERIVER_DOTTED):
                pass                  # derivation: does not consume
            else:
                # opaque call: assume the callee may sample the key
                for k in self._key_args(e):
                    self.state[k] = "consumed"

    # -- statements ------------------------------------------------------
    def bind_fresh(self, target):
        if isinstance(target, ast.Name):
            self.state[target.id] = "fresh"
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.bind_fresh(t)
        elif isinstance(target, ast.Starred):
            self.bind_fresh(target.value)

    def bind_clear(self, target):
        if isinstance(target, ast.Name):
            self.state.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.bind_clear(t)
        elif isinstance(target, ast.Starred):
            self.bind_clear(target.value)

    def stmts(self, body):
        for s in body:
            self.stmt(s)

    def stmt(self, s):
        if isinstance(s, (ast.Assign, ast.AnnAssign)):
            v = s.value
            self.expr(v)
            targets = s.targets if isinstance(s, ast.Assign) else \
                [s.target]
            derive = isinstance(v, ast.Call) and \
                self._is(v, _DERIVER_DOTTED)
            alias = isinstance(v, ast.Name) and v.id in self.state
            for t in targets:
                if derive:
                    self.bind_fresh(t)
                elif alias and isinstance(t, ast.Name):
                    self.state[t.id] = self.state[v.id]
                else:
                    self.bind_clear(t)
        elif isinstance(s, ast.AugAssign):
            self.expr(s.value)
            self.bind_clear(s.target)
        elif isinstance(s, (ast.If,)):
            self.expr(s.test)
            before = dict(self.state)
            self.stmts(s.body)
            after_body = self.state
            self.state = dict(before)
            self.stmts(s.orelse)
            merged = {}
            for k in set(after_body) | set(self.state):
                a, b = after_body.get(k), self.state.get(k)
                merged[k] = "consumed" if "consumed" in (a, b) else \
                    (a or b)
            self.state = merged
        elif isinstance(s, (ast.For, ast.While)):
            if isinstance(s, ast.For):
                self.expr(s.iter)
                self.bind_clear(s.target)
            else:
                self.expr(s.test)
            # run the body twice: a key consumed on iteration 1 and
            # sampled again on iteration 2 is the classic reuse bug
            self.stmts(s.body)
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.Return):
            self.expr(s.value)
        elif isinstance(s, ast.Expr):
            self.expr(s.value)
        elif isinstance(s, ast.With):
            for item in s.items:
                self.expr(item.context_expr)
            self.stmts(s.body)
        elif isinstance(s, ast.Try):
            self.stmts(s.body)
            for h in s.handlers:
                self.stmts(h.body)
            self.stmts(s.orelse)
            self.stmts(s.finalbody)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            pass                      # nested scopes walked separately


def check_prng(mi: ModuleIndex) -> list:
    out = []
    seen = set()
    for fi in mi.funcs.values():
        node = fi.node
        if isinstance(node, ast.Lambda):
            continue
        w = _KeyWalk(mi, mi.path)
        for p in fi.params:
            if _KEYISH.search(p):
                w.state[p] = "fresh"
        w.stmts(node.body)
        for f in w.findings:
            k = (f.rule, f.line, f.message)
            if k not in seen:
                seen.add(k)
                out.append(f)
    return out


# ---------------------------------------------------------------------------
# RL006 — dtype discipline in bit-exact modules
# ---------------------------------------------------------------------------

def check_dtypes(mi: ModuleIndex) -> list:
    out = []
    for node in ast.walk(mi.tree):
        if isinstance(node, ast.Attribute) and resolves_to(
                mi, node, *_F64_ATTRS):
            out.append(Finding(
                "RL006", mi.path, node.lineno,
                f"{dotted_name(node)} in a bit-exact module: results "
                "must not depend on the x64 mode"))
        elif isinstance(node, ast.Call):
            for kw in node.keywords:
                if kw.arg != "dtype":
                    continue
                v = kw.value
                if isinstance(v, ast.Constant) and v.value in \
                        _F64_STRINGS:
                    out.append(Finding(
                        "RL006", mi.path, node.lineno,
                        f'dtype="{v.value}" in a bit-exact module'))
                elif isinstance(v, ast.Name) and v.id == "float":
                    out.append(Finding(
                        "RL006", mi.path, node.lineno,
                        "dtype=float resolves to float64 under x64 in "
                        "a bit-exact module"))
            if (isinstance(node.func, ast.Attribute)
                    and node.func.attr == "astype" and node.args):
                a = node.args[0]
                if isinstance(a, ast.Constant) and a.value in \
                        _F64_STRINGS:
                    out.append(Finding(
                        "RL006", mi.path, node.lineno,
                        f'.astype("{a.value}") in a bit-exact module'))
    out.sort(key=lambda f: f.line)
    return out
