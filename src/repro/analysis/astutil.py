"""AST indexing for the contract linter: modules, functions, imports,
and cross-module call resolution.

The linter works on a closed set of files (the lint scope). Each file
becomes a :class:`ModuleIndex` — its parsed tree, every function
definition (top-level, nested, lambdas get synthetic names) with a
dotted qualname, and the module's import aliases — and
:class:`Project` stitches them into one symbol table so a call like
``ops.switch_step(...)`` in ``core/simulator.py`` resolves to the
``switch_step`` function object in ``kernels/ops.py``.

Resolution is deliberately name-based and approximate: a miss means a
checker under-reports, never crashes. That is the right tradeoff for a
lint pass — the runtime sanitizers (analysis/sanitizer.py) backstop
what static resolution cannot see.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def dotted_name(node: ast.AST) -> str | None:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclass
class FuncInfo:
    module: "ModuleIndex"
    qualname: str
    node: ast.AST                   # FunctionDef / Lambda
    parent: "FuncInfo | None" = None
    children: dict = field(default_factory=dict)   # name -> FuncInfo

    @property
    def name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    @property
    def params(self) -> list[str]:
        a = self.node.args
        names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
        if a.vararg:
            names.append(a.vararg.arg)
        if a.kwarg:
            names.append(a.kwarg.arg)
        return names

    @property
    def lineno(self) -> int:
        return self.node.lineno

    def key(self) -> tuple:
        return (self.module.modname, self.qualname)


@dataclass
class ModuleIndex:
    path: str                       # repo-relative, posix
    modname: str                    # e.g. "repro.core.simulator"
    tree: ast.Module
    source: str
    funcs: dict = field(default_factory=dict)       # qualname -> FuncInfo
    top_level: dict = field(default_factory=dict)   # name -> FuncInfo
    imports: dict = field(default_factory=dict)     # alias -> module path
    from_imports: dict = field(default_factory=dict)  # name -> "mod.name"

    def func_of_node(self, fnode: ast.AST) -> "FuncInfo | None":
        for fi in self.funcs.values():
            if fi.node is fnode:
                return fi
        return None


def _module_name(path: Path, root: Path) -> str:
    rel = path.relative_to(root)
    parts = list(rel.with_suffix("").parts)
    if parts[0] == "src":
        parts = parts[1:]
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def index_module(path: Path, root: Path, source: str | None = None
                 ) -> ModuleIndex:
    src = path.read_text() if source is None else source
    tree = ast.parse(src, filename=str(path))
    mi = ModuleIndex(path=path.relative_to(root).as_posix(),
                     modname=_module_name(path, root), tree=tree,
                     source=src)

    lambda_n = [0]

    def visit(node, parent: FuncInfo | None, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}" if prefix else child.name
                fi = FuncInfo(mi, q, child, parent)
                mi.funcs[q] = fi
                if parent is None:
                    mi.top_level[child.name] = fi
                else:
                    parent.children[child.name] = fi
                visit(child, fi, q + ".")
            elif isinstance(child, ast.Lambda):
                lambda_n[0] += 1
                q = f"{prefix}<lambda#{lambda_n[0]}>"
                fi = FuncInfo(mi, q, child, parent)
                mi.funcs[q] = fi
                if parent is not None:
                    parent.children.setdefault(q.rsplit('.', 1)[-1], fi)
                visit(child, fi, q + ".")
            elif isinstance(child, ast.ClassDef):
                # methods get Class.name qualnames; no nesting support
                # needed beyond that for this codebase
                visit(child, parent, (prefix + child.name + "."))
            else:
                visit(child, parent, prefix)

    visit(tree, None, "")

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mi.imports[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                mi.from_imports[a.asname or a.name] = \
                    f"{node.module}.{a.name}"
    return mi


@dataclass
class Project:
    root: Path
    modules: dict = field(default_factory=dict)   # modname -> ModuleIndex

    def add(self, mi: ModuleIndex):
        self.modules[mi.modname] = mi

    def by_path(self, relpath: str) -> ModuleIndex | None:
        for mi in self.modules.values():
            if mi.path == relpath:
                return mi
        return None

    # ---- call resolution -------------------------------------------------
    def resolve_call(self, call_func: ast.AST, scope: FuncInfo | None,
                     mi: ModuleIndex) -> FuncInfo | None:
        """Resolve a call's func expression to a FuncInfo in scope.

        Handles: bare names (lexical scope chain, then module top
        level, then from-imports), ``alias.attr`` where ``alias`` is an
        imported module in the project, and ``from x import f`` names.
        """
        if isinstance(call_func, ast.Name):
            name = call_func.id
            f = scope
            while f is not None:
                if name in f.children:
                    return f.children[name]
                f = f.parent
            if name in mi.top_level:
                return mi.top_level[name]
            target = mi.from_imports.get(name)
            if target:
                modname, _, fname = target.rpartition(".")
                other = self.modules.get(modname)
                if other:
                    return other.top_level.get(fname)
            return None
        dn = dotted_name(call_func)
        if dn and "." in dn:
            base, _, attr = dn.rpartition(".")
            # alias.attr -> imported module's top-level function.
            # ``from repro.core import gating`` lands in from_imports
            # with value "repro.core.gating" (module, not symbol).
            target_mod = mi.imports.get(base) or mi.from_imports.get(base)
            if target_mod and target_mod in self.modules:
                return self.modules[target_mod].top_level.get(attr)
        return None

    def iter_functions(self):
        for mi in self.modules.values():
            for fi in mi.funcs.values():
                yield fi


def load_project(root: Path, paths: list[Path]) -> Project:
    proj = Project(root=root)
    for p in paths:
        proj.add(index_module(p, root))
    return proj


def resolves_to(mi: ModuleIndex, node: ast.AST, *dotted: str) -> bool:
    """True if ``node`` is a reference to any of the given fully-dotted
    names, honouring the module's import aliases (``jnp.float64``
    matches ``jax.numpy.float64`` when jnp aliases jax.numpy)."""
    dn = dotted_name(node)
    if dn is None:
        return False
    for want in dotted:
        if dn == want:
            return True
        head, _, rest = dn.partition(".")
        real = mi.imports.get(head)
        if real and rest and f"{real}.{rest}" == want:
            return True
        frm = mi.from_imports.get(head)
        if frm:
            cand = f"{frm}.{rest}" if rest else frm
            if cand == want:
                return True
    return False
