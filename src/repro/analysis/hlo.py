"""Compiled-artifact text/stat parsers: collectives, host callbacks,
input-output aliasing, memory and cost summaries.

Promoted from ``launch/hlo_analysis.py`` (which re-exports for its
dry-run callers) so the artifact auditor (analysis/artifact.py) and the
launch-side dry-run accounting share one HLO vocabulary.

collective_bytes is NOT in ``compiled.cost_analysis()``; we parse the
post-SPMD HLO and sum per-op result sizes, converting to per-device
link-bytes with ring-algorithm factors:

    all-gather          R * (g-1)/g          (R = result bytes, g = group)
    all-reduce          2 * R * (g-1)/g
    reduce-scatter      R * (g-1)             (operand = R*g)
    all-to-all          R * (g-1)/g
    collective-permute  R
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(?:\([^)]*\)|(?P<dtype>\w+)\[(?P<shape>[\d,]*)\][^ ]*)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_TUPLE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

#: host-communication HLO ops: any of these inside a supposedly
#: device-resident chunk program is an RL008 violation
_HOST_OP_RE = re.compile(
    r"=\s+(?:\((?:[^()]|\([^()]*\))*\)|\S+)\s+"
    r"(?P<op>infeed|outfeed|send|send-done|recv|recv-done)\(")
_CUSTOM_CALL_RE = re.compile(r'custom_call_target="(?P<target>[^"]+)"')
#: custom-call targets that round-trip through the host (io_callback,
#: jax.debug.print/callback, host_callback — all lower to one of these
#: python-callback trampolines)
_CALLBACK_TARGET_RE = re.compile(r"callback|host", re.IGNORECASE)

_ALIAS_HEADER_RE = re.compile(r"input_output_alias=\{(?P<body>[^\n]*)\}")
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\(\d+")


def _nbytes(dtype: str, shape: str) -> int:
    n = 1
    for s in shape.split(","):
        if s:
            n *= int(s)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    ops: list = field(default_factory=list)   # (op, result_bytes, group, link_bytes)

    @property
    def total_result_bytes(self) -> float:
        return sum(o[1] for o in self.ops)

    @property
    def total_link_bytes(self) -> float:
        return sum(o[3] for o in self.ops)

    def by_op(self) -> dict:
        out: dict[str, dict] = {}
        for op, rb, g, lb in self.ops:
            d = out.setdefault(op, {"count": 0, "result_bytes": 0.0,
                                    "link_bytes": 0.0})
            d["count"] += 1
            d["result_bytes"] += rb
            d["link_bytes"] += lb
        return out


def _link_bytes(op: str, result_bytes: float, g: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * result_bytes * (g - 1) / g
    if op == "all-gather":
        return result_bytes * (g - 1) / g
    if op == "reduce-scatter":
        return result_bytes * (g - 1)
    if op == "all-to-all":
        return result_bytes * (g - 1) / g
    return float(result_bytes)            # collective-permute


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:
            continue                       # avoid double-count of async pairs
        if m.group("dtype"):
            rb = _nbytes(m.group("dtype"), m.group("shape"))
        else:
            # tuple result: sum the element sizes inside (...)
            head = line.split("=", 1)[1].split(op)[0]
            rb = sum(_nbytes(d, s) for d, s in _TUPLE_RE.findall(head))
        gm = _GROUPS_RE.search(line)
        g = int(gm.group(2)) if gm else 1
        stats.ops.append((op, float(rb), g, _link_bytes(op, float(rb), g)))
    return stats


def find_host_ops(hlo_text: str) -> list:
    """Host-communication sites in compiled HLO text: infeed/outfeed/
    send/recv ops plus custom-calls whose target is a host-callback
    trampoline. Returns op/target names, one per occurrence."""
    out = []
    for line in hlo_text.splitlines():
        m = _HOST_OP_RE.search(line)
        if m:
            out.append(m.group("op"))
            continue
        m = _CUSTOM_CALL_RE.search(line)
        if m and _CALLBACK_TARGET_RE.search(m.group("target")):
            out.append(f'custom-call:{m.group("target")}')
    return out


def count_alias_entries(hlo_text: str) -> int:
    """Number of input->output buffer aliases declared in the compiled
    module header (``input_output_alias={ {0}: (31, {}, may-alias), ...``
    — what ``donate_argnames`` must turn into for donation to be real)."""
    m = _ALIAS_HEADER_RE.search(hlo_text)
    if not m:
        return 0
    return len(_ALIAS_ENTRY_RE.findall(m.group("body")))


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    keys = ["generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "alias_size_in_bytes",
            "temp_size_in_bytes"]
    return {k: int(getattr(ma, k, 0) or 0) for k in keys}


def cost_stats(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):     # some jax versions wrap it
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0))}
