"""Traced-value taint analysis for RL001/RL003.

Roots: every function handed to ``jax.jit`` / ``pl.pallas_call`` /
``jax.lax.scan|cond|while_loop|fori_loop`` / ``jax.vmap`` (as a
decorator or a callsite argument, possibly wrapped in
``functools.partial``). Parameters bound statically — ``static_argnames``
on jit, kwargs/leading positionals bound by ``partial`` on a pallas
kernel — start untainted; everything else a root receives is a traced
value.

Propagation is interprocedural to a fixpoint: when a traced function
passes a tainted value into another function the linter can resolve,
that callee joins the traced-reachable set with those parameters
tainted. Taint sets only grow, so the worklist terminates.

Untaint rules (the false-positive killers, each one load-bearing for
the shipped tree):

* ``x is None`` / ``x is not None`` comparisons are static — the
  None-ness of a traced argument is part of the trace signature;
* ``.shape`` / ``.ndim`` / ``.dtype`` / ``.size`` reads are static
  metadata;
* ``len()``, ``isinstance()``, ``type()``, ``range()`` results are
  static.

Within traced-reachable functions the engine emits:

* RL001 for ``if``/``while``/``assert``/ternary tests on tainted
  values and for ``float()/int()/bool()/complex()`` or
  ``.item()/.tolist()`` coercions of tainted values;
* RL003 for ``np.asarray``/``np.array`` over tainted values and for
  ``for``-loops iterating the result of a jnp/jax call (array
  ``__iter__`` unrolls at trace time: a hidden transfer + shape-many
  retraces).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path

from .astutil import (FuncInfo, ModuleIndex, Project, dotted_name,
                      resolves_to)
from .findings import Finding

_JIT = ("jax.jit",)
_PALLAS = ("jax.experimental.pallas.pallas_call",)
_SCAN = ("jax.lax.scan",)
_ONE_FN = {  # transform dotted name -> positions of function-valued args
    "jax.jit": (0,), "jax.vmap": (0,), "jax.grad": (0,),
    "jax.value_and_grad": (0,), "jax.checkpoint": (0,),
    "jax.remat": (0,), "jax.pmap": (0,),
    "jax.experimental.pallas.pallas_call": (0,),
    "jax.lax.scan": (0,), "jax.lax.map": (0,),
    "jax.lax.cond": (1, 2), "jax.lax.switch": (),  # branches = list arg
    "jax.lax.while_loop": (0, 1), "jax.lax.fori_loop": (2,),
    "jax.lax.associative_scan": (0,),
}
_STATIC_META = {"shape", "ndim", "dtype", "size"}
_STATIC_CALLS = {"len", "isinstance", "type", "range", "hasattr",
                 "enumerate", "zip", "sorted", "list", "tuple", "dict",
                 "set", "min", "max"}
# min/max/list/... of a tainted value IS tainted-ish, but branch-on-it
# is what RL001 cares about and those appear over static shape math in
# this tree; keep them static except the true coercions below
_COERCE_CALLS = {"float", "int", "bool", "complex"}
_COERCE_METHODS = {"item", "tolist"}


@dataclass
class TaintResult:
    findings: list = field(default_factory=list)
    #: (modname, qualname) -> set of tainted parameter names
    traced: dict = field(default_factory=dict)

    def is_traced(self, fi: FuncInfo) -> bool:
        return fi.key() in self.traced


def _str_elems(node) -> set:
    """Collect string constants from a Constant/Tuple/List expr."""
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.add(n.value)
    return out


def _own_returns(fnode):
    """Return statements of a def, skipping nested function bodies."""
    out = []

    def scan(body):
        for s in body:
            if isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda)):
                continue
            if isinstance(s, ast.Return):
                out.append(s)
            for attr in ("body", "orelse", "finalbody"):
                sub = getattr(s, attr, None)
                if isinstance(sub, list):
                    scan(sub)
            for h in getattr(s, "handlers", []):
                scan(h.body)

    scan(fnode.body)
    return out


def _func_from_expr(expr, scope, mi: ModuleIndex, proj: Project,
                    depth: int = 0):
    """Resolve a function-valued expression to (FuncInfo, static_params).

    Peels ``functools.partial`` (bound kwargs and leading positionals
    become static params) and nested transform wrappers like
    ``jax.jit(partial(f, ...))``; follows local aliases
    (``step = make_sim_step(hull)``) and closure factories (a project
    function whose return value is one of its own nested defs), so the
    simulator's ``jax.vmap(make_sim_step(...))`` hot step is rooted.
    """
    if depth > 8:
        return None, set()
    if isinstance(expr, ast.Lambda):
        return mi.func_of_node(expr), set()
    if isinstance(expr, (ast.Name, ast.Attribute)):
        fi = proj.resolve_call(expr, scope, mi)
        if fi is not None:
            return fi, set()
        if isinstance(expr, ast.Name):
            f = scope
            while f is not None:
                for node in ast.walk(f.node):
                    if (isinstance(node, ast.Assign)
                            and len(node.targets) == 1
                            and isinstance(node.targets[0], ast.Name)
                            and node.targets[0].id == expr.id):
                        got, st = _func_from_expr(node.value, f, mi,
                                                  proj, depth + 1)
                        if got is not None:
                            return got, st
                f = f.parent
        return None, set()
    if isinstance(expr, ast.Call):
        if resolves_to(mi, expr.func, "functools.partial") and expr.args:
            fi, statics = _func_from_expr(expr.args[0], scope, mi, proj,
                                          depth + 1)
            if fi is not None:
                statics = set(statics)
                statics |= {kw.arg for kw in expr.keywords if kw.arg}
                n_pos = len(expr.args) - 1
                statics |= set(fi.params[:n_pos])
            return fi, statics
        if any(resolves_to(mi, expr.func, t) for t in _ONE_FN
               ) and expr.args:
            return _func_from_expr(expr.args[0], scope, mi, proj,
                                   depth + 1)
        # closure factory: f() returning one of f's own nested defs
        target = proj.resolve_call(expr.func, scope, mi)
        if target is not None and isinstance(
                target.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for ret in _own_returns(target.node):
                if (isinstance(ret.value, ast.Name)
                        and ret.value.id in target.children):
                    return target.children[ret.value.id], set()
    return None, set()


def _transform_target(mi, call: ast.Call):
    """Dotted transform name if this call is a jax transform we root."""
    for t in _ONE_FN:
        if resolves_to(mi, call.func, t):
            return t
    return None


def discover_roots(proj: Project):
    """Yield (FuncInfo, tainted_param_names) for every traced root."""
    for mi in proj.modules.values():
        # decorator roots
        for fi in mi.funcs.values():
            node = fi.node
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                statics = set()
                is_root = resolves_to(mi, dec, *_JIT)
                if isinstance(dec, ast.Call) and resolves_to(
                        mi, dec.func, *_JIT, "functools.partial"):
                    inner_jit = resolves_to(mi, dec.func, *_JIT)
                    part_jit = (resolves_to(mi, dec.func,
                                            "functools.partial")
                                and dec.args
                                and resolves_to(mi, dec.args[0], *_JIT))
                    if inner_jit or part_jit:
                        is_root = True
                        for kw in dec.keywords:
                            if kw.arg in ("static_argnames",
                                          "static_argnums"):
                                statics |= _str_elems(kw.value)
                if is_root:
                    yield fi, set(fi.params) - statics
        # callsite roots
        for fnode in ast.walk(mi.tree):
            if not isinstance(fnode, ast.Call):
                continue
            t = _transform_target(mi, fnode)
            if t is None:
                continue
            scope = _enclosing(mi, fnode)
            statics = set()
            if t == "jax.jit":
                for kw in fnode.keywords:
                    if kw.arg == "static_argnames":
                        statics |= _str_elems(kw.value)
            for pos in _ONE_FN[t]:
                if pos >= len(fnode.args):
                    continue
                fi, pstat = _func_from_expr(fnode.args[pos], scope, mi,
                                            proj)
                if fi is not None:
                    yield fi, set(fi.params) - statics - pstat


def _enclosing(mi: ModuleIndex, node) -> FuncInfo | None:
    """Innermost FuncInfo whose node contains ``node`` (by position)."""
    best = None
    for fi in mi.funcs.values():
        fn = fi.node
        if (fn.lineno <= node.lineno <= getattr(fn, "end_lineno",
                                                fn.lineno)):
            if best is None or fn.lineno >= best.node.lineno:
                best = fi
    return best


class _Engine:
    """One pass of the per-function taint walk (RL001/RL003-traced)."""

    def __init__(self, fi: FuncInfo, tainted: set, proj: Project,
                 on_call, emit):
        self.fi = fi
        self.mi = fi.module
        self.tainted = set(tainted)
        self.proj = proj
        self.on_call = on_call
        self.emit = emit

    # ---- expression taint ----------------------------------------------
    def tval(self, e) -> bool:
        if e is None or isinstance(e, ast.Constant):
            return False
        if isinstance(e, ast.Name):
            return e.id in self.tainted
        if isinstance(e, ast.Attribute):
            if e.attr in _STATIC_META:
                self.tval(e.value)
                return False
            return self.tval(e.value)
        if isinstance(e, ast.Subscript):
            return self.tval(e.value) | self.tval(e.slice)
        if isinstance(e, ast.Compare):
            operand_taint = self.tval(e.left) | any(
                self.tval(c) for c in e.comparators)
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in e.ops):
                return False          # None-ness is trace-static
            return operand_taint
        if isinstance(e, ast.BoolOp):
            return any(self.tval(v) for v in e.values)
        if isinstance(e, ast.BinOp):
            return self.tval(e.left) | self.tval(e.right)
        if isinstance(e, ast.UnaryOp):
            return self.tval(e.operand)
        if isinstance(e, ast.IfExp):
            if self.tval(e.test):
                self.emit("RL001", e.lineno,
                          "ternary condition on a traced value in "
                          f"traced function {self.fi.qualname}")
            return self.tval(e.body) | self.tval(e.orelse)
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            return any(self.tval(x) for x in e.elts)
        if isinstance(e, ast.Dict):
            return any(self.tval(k) for k in e.keys if k is not None) | \
                any(self.tval(v) for v in e.values)
        if isinstance(e, ast.Starred):
            return self.tval(e.value)
        if isinstance(e, ast.JoinedStr):
            return any(self.tval(v) for v in e.values)
        if isinstance(e, ast.FormattedValue):
            return self.tval(e.value)
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            t = False
            for gen in e.generators:
                t |= self.tval(gen.iter)
                for cond in gen.ifs:
                    if self.tval(cond):
                        self.emit("RL001", cond.lineno,
                                  "comprehension filter on a traced "
                                  "value in traced function "
                                  f"{self.fi.qualname}")
            if isinstance(e, ast.DictComp):
                t |= self.tval(e.key) | self.tval(e.value)
            else:
                t |= self.tval(e.elt)
            return t
        if isinstance(e, ast.Call):
            return self.call(e)
        if isinstance(e, ast.Lambda):
            return False
        if isinstance(e, ast.NamedExpr):
            t = self.tval(e.value)
            self.bind(e.target, t)
            return t
        # conservative default: tainted if any child expression is
        return any(self.tval(c) for c in ast.iter_child_nodes(e)
                   if isinstance(c, ast.expr))

    # ---- calls ----------------------------------------------------------
    def call(self, e: ast.Call) -> bool:
        arg_taints = [self.tval(a) for a in e.args]
        kw_taints = {kw.arg: self.tval(kw.value) for kw in e.keywords}
        any_taint = any(arg_taints) or any(kw_taints.values())

        fname = e.func.id if isinstance(e.func, ast.Name) else None
        if fname in _STATIC_CALLS:
            return False
        if fname in _COERCE_CALLS and any_taint:
            self.emit("RL001", e.lineno,
                      f"{fname}() coerces a traced value to host "
                      f"Python in traced function {self.fi.qualname}")
            return False
        if isinstance(e.func, ast.Attribute):
            if e.func.attr in _COERCE_METHODS and self.tval(e.func.value):
                self.emit("RL001", e.lineno,
                          f".{e.func.attr}() pulls a traced value to "
                          "host in traced function "
                          f"{self.fi.qualname}")
                return False
            if e.func.attr in ("asarray", "array") and resolves_to(
                    self.mi, e.func, "numpy.asarray", "numpy.array"):
                if any_taint:
                    self.emit("RL003", e.lineno,
                              "np." + e.func.attr + " on a traced value"
                              " forces a device->host transfer inside "
                              f"traced function {self.fi.qualname}")
                return any_taint
            self.tval(e.func.value)

        # interprocedural propagation into resolvable project callees
        # (including local aliases / closure-factory results)
        target, _ = _func_from_expr(e.func, self.fi, self.mi, self.proj)
        if target is not None and target.key() != self.fi.key():
            params = target.params
            hit = set()
            for i, t in enumerate(arg_taints):
                if t and i < len(params):
                    hit.add(params[i])
            for k, t in kw_taints.items():
                if t and k in params:
                    hit.add(k)
            if hit:
                self.on_call(target, hit)
        return any_taint

    # ---- statements -----------------------------------------------------
    def bind(self, target, tainted: bool):
        if isinstance(target, ast.Name):
            if tainted:
                self.tainted.add(target.id)
            else:
                self.tainted.discard(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for t in target.elts:
                self.bind(t, tainted)
        elif isinstance(target, ast.Starred):
            self.bind(target.value, tainted)
        elif isinstance(target, (ast.Attribute, ast.Subscript)):
            self.tval(target.value)

    def stmts(self, body):
        for s in body:
            self.stmt(s)

    def stmt(self, s):
        if isinstance(s, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = s.value
            t = self.tval(value) if value is not None else False
            targets = s.targets if isinstance(s, ast.Assign) else \
                [s.target]
            if isinstance(s, ast.AugAssign):
                t = t or self.tval(s.target)
            for tgt in targets:
                self.bind(tgt, t)
        elif isinstance(s, ast.If):
            if self.tval(s.test):
                self.emit("RL001", s.test.lineno,
                          "if-statement on a traced value in traced "
                          f"function {self.fi.qualname}")
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.While):
            if self.tval(s.test):
                self.emit("RL001", s.test.lineno,
                          "while-loop on a traced value in traced "
                          f"function {self.fi.qualname}")
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.Assert):
            if self.tval(s.test):
                self.emit("RL001", s.lineno,
                          "assert on a traced value in traced function "
                          f"{self.fi.qualname} (use checkify or a "
                          "validate gate)")
        elif isinstance(s, ast.For):
            it = s.iter
            if isinstance(it, ast.Call):
                dn = dotted_name(it.func) or ""
                head = dn.split(".")[0]
                real = self.fi.module.imports.get(head, "")
                frm = self.fi.module.from_imports.get(head, "")
                if (real.startswith("jax") or frm.startswith("jax")
                        or head == "jax"):
                    self.emit("RL003", s.lineno,
                              "iterating a jax array unrolls via host "
                              "__iter__ (one transfer per element) in "
                              f"{self.fi.qualname}")
            self.bind(s.target, self.tval(it))
            self.stmts(s.body)
            self.stmts(s.orelse)
        elif isinstance(s, ast.Return):
            self.tval(s.value)
        elif isinstance(s, ast.Expr):
            self.tval(s.value)
        elif isinstance(s, ast.With):
            for item in s.items:
                t = self.tval(item.context_expr)
                if item.optional_vars is not None:
                    self.bind(item.optional_vars, t)
            self.stmts(s.body)
        elif isinstance(s, ast.Try):
            self.stmts(s.body)
            for h in s.handlers:
                self.stmts(h.body)
            self.stmts(s.orelse)
            self.stmts(s.finalbody)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef,
                            ast.ClassDef)):
            pass   # nested defs are analyzed when rooted or called
        elif isinstance(s, (ast.Raise,)):
            if s.exc is not None:
                self.tval(s.exc)
        # Pass/Break/Continue/Import/Global/Nonlocal/Delete: no taint

    def run(self):
        node = self.fi.node
        body = node.body if not isinstance(node, ast.Lambda) else None
        # two passes so names assigned late but used early (rare, but
        # loops reorder) settle; taint only grows within a run
        for _ in range(2):
            if body is None:
                self.tval(node.body)
            else:
                self.stmts(body)
        return self.tainted


def analyze(proj: Project) -> TaintResult:
    res = TaintResult()
    seen_findings = set()
    state: dict = {}          # key -> set of tainted params
    processed: set = set()
    work: list = []
    by_key = {fi.key(): fi for fi in proj.iter_functions()}

    def ensure(fi: FuncInfo, params: set):
        key = fi.key()
        cur = state.setdefault(key, set())
        grew = not params <= cur
        cur |= params
        if grew or key not in processed:
            if key not in [k for k, _ in work]:
                work.append((key, fi))

    for fi, params in discover_roots(proj):
        ensure(fi, params)

    rounds = 0
    while work and rounds < 10_000:
        rounds += 1
        key, fi = work.pop(0)
        processed.add(key)

        def emit(rule, line, msg, _fi=fi):
            f = Finding(rule, _fi.module.path, line, msg)
            if (rule, f.path, line, msg) not in seen_findings:
                seen_findings.add((rule, f.path, line, msg))
                res.findings.append(f)

        eng = _Engine(fi, state[key], proj, ensure, emit)
        eng.run()

    res.traced = {k: set(v) for k, v in state.items()}
    res.findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return res
