"""--dead-code: module-level reachability over the repro import graph.

Roots are the things that actually execute: every ``benchmarks/*.py``
and ``examples/*.py`` entry point, ``repro.core.simulator`` (the
library surface ``run_sweep``/``run_sim`` callers import), and the
linter's own ``python -m repro.analysis`` entry. Tests are
deliberately NOT roots — a module only a test imports is exactly the
inventory this report exists to surface.

The seed trees that predate the simulator (models/, optim/, configs/,
train/, serving/, distributed/) are expected to show up unreachable;
they are marked ``exempt`` (mirroring the registry's lint_exempt
list) rather than deleted — models/attention.py and models/rwkv6.py
are the exception and stay reachable as the kernel oracles via
kernels/ref.py.
"""
from __future__ import annotations

import ast
from pathlib import Path


def _modname(path: Path, src: Path) -> str:
    rel = path.relative_to(src).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _repro_imports(tree: ast.Module, cur_mod: str, known: set) -> set:
    out = set()

    def add(name: str):
        # an import of a package also executes its __init__; an
        # imported symbol may itself be a submodule
        if name in known:
            out.add(name)
        parts = name.split(".")
        for i in range(1, len(parts)):
            pkg = ".".join(parts[:i])
            if pkg in known:
                out.add(pkg)

    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "repro":
                    add(a.name)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:
                parent = cur_mod.split(".")
                parent = parent[:len(parent) - node.level]
                base = ".".join(parent + ([base] if base else []))
            if base.split(".")[0] != "repro":
                continue
            add(base)
            for a in node.names:
                add(f"{base}.{a.name}")
    return out


def dead_code_report(root: Path, exempt_trees: list) -> dict:
    src = root / "src"
    files = {}
    for p in sorted((src / "repro").rglob("*.py")):
        files[_modname(p, src)] = p
    known = set(files)

    edges = {}
    for mod, p in files.items():
        try:
            tree = ast.parse(p.read_text())
        except SyntaxError:
            edges[mod] = set()
            continue
        edges[mod] = _repro_imports(tree, mod, known)

    # simulator = the library surface; __main__ = `python -m
    # repro.analysis`; sanitizer = the conftest-wired runtime leg
    roots = {"repro.core.simulator", "repro.analysis.__main__",
             "repro.analysis.sanitizer"}
    bench_files = []
    for dirname in ("benchmarks", "examples"):
        d = root / dirname
        if not d.is_dir():
            continue
        for p in sorted(d.glob("*.py")):
            bench_files.append(p.relative_to(root).as_posix())
            try:
                tree = ast.parse(p.read_text())
            except SyntaxError:
                continue
            roots |= _repro_imports(tree, "", known)
    roots &= known

    reachable = set()
    work = sorted(roots)
    while work:
        m = work.pop()
        if m in reachable:
            continue
        reachable.add(m)
        work.extend(edges.get(m, ()))

    def relpath(mod):
        return files[mod].relative_to(root).as_posix()

    def is_exempt(mod):
        rp = relpath(mod)
        return any(rp.startswith(e.rstrip("/") + "/") or rp == e
                   for e in exempt_trees)

    unreachable = []
    loc_dead = 0
    for mod in sorted(known - reachable):
        loc = len(files[mod].read_text().splitlines())
        loc_dead += loc
        unreachable.append({"module": mod, "path": relpath(mod),
                            "loc": loc, "exempt": is_exempt(mod)})
    exempt_but_reachable = sorted(
        mod for mod in reachable if is_exempt(mod))

    return {
        "roots": sorted(roots),
        "bench_entry_points": bench_files,
        "reachable": {m: relpath(m) for m in sorted(reachable)},
        "unreachable": unreachable,
        "exempt_but_reachable": exempt_but_reachable,
        "summary": {
            "n_modules": len(known),
            "n_reachable": len(reachable),
            "n_unreachable": len(unreachable),
            "loc_unreachable": loc_dead,
        },
    }
