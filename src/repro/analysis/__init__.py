"""Contract-aware static analysis for the sweep engine (PR 7).

Six PRs of performance contracts — one compile per (site, batch-shape),
exactly one host transfer per ``run_sweep``, Scenario knobs as array
leaves, PRNG ``fold_in`` discipline, zero-rate bit-parity — were
runtime pins only. This package makes them machine-checkable at
analysis time, per file, with named rules (mirrored in ROADMAP.md
"Static contracts (as of PR 7)"):

* **RL001 traced-control-flow** — no Python ``if``/``while``/``assert``
  or ``float()/int()/bool()/.item()`` on values derived from traced
  arguments inside any function reachable from a ``jax.jit`` /
  ``pl.pallas_call`` / ``lax.scan`` site (taint.py: interprocedural
  taint from traced roots).
* **RL002 compile-site-registry** — every ``jit``/``pallas_call``/
  ``lax.scan`` callsite is declared in ``compile_sites.toml`` with its
  expected trace multiplicity; registry drift vs the code or vs the
  ``TRACE_COUNT`` probe is a finding (registry.py).
* **RL003 host-transfer-smell** — ``jax.device_get`` /
  ``.block_until_ready()`` in hot-loop modules outside the blessed
  fetch points (``[[blessed_transfer]]``), plus ``np.asarray`` /
  array-``__iter__`` over traced values inside traced functions.
* **RL004 scenario-leaf-sync** — Scenario/SimParams fields must match
  the registry inventory: fingerprint knobs == ``FAULT_KNOBS``, every
  param validated in ``__post_init__`` or exempted with a reason, the
  schema version pinned on both sides, no dead Scenario leaves.
* **RL005 prng-discipline** — a key feeding two sampling calls without
  an intervening ``split``/``fold_in`` (checkers.py).
* **RL006 dtype-discipline** — float64 literals/dtypes in bit-exact
  kernel/ref/gating modules.

Workflow: ``python -m repro.analysis --check`` (CI lint-canary);
``--json``/``--dead-code`` write reports under ``results/``. To bless a
violation, either register it (compile site, blessed transfer,
validation exemption — all reviewed registry edits) or annotate the
line with ``# repro-lint: disable=RULE(reason)``; reasons are
mandatory and the total suppression count is baselined by
``max_suppressions`` (it can only go down silently, never up).

Runtime cross-validation lives in sanitizer.py: a conftest fixture
arms ``jax.transfer_guard_device_to_host("disallow")`` and a
``jax.log_compiles`` recompile detector around the sweep tests,
asserting the planner pipeline's one-trace-per-bucket contract with
per-hull attribution (the ``TRACE_HOOK`` seam in simulator.py).
"""
from .engine import LintReport, run_lint          # noqa: F401
from .findings import Finding, RULES              # noqa: F401
from .registry import load_config                 # noqa: F401
