"""Contract-aware static analysis for the sweep engine (PR 7).

Six PRs of performance contracts — one compile per (site, batch-shape),
exactly one host transfer per ``run_sweep``, Scenario knobs as array
leaves, PRNG ``fold_in`` discipline, zero-rate bit-parity — were
runtime pins only. This package makes them machine-checkable at
analysis time, per file, with named rules (mirrored in ROADMAP.md
"Static contracts (as of PR 7)"):

* **RL001 traced-control-flow** — no Python ``if``/``while``/``assert``
  or ``float()/int()/bool()/.item()`` on values derived from traced
  arguments inside any function reachable from a ``jax.jit`` /
  ``pl.pallas_call`` / ``lax.scan`` site (taint.py: interprocedural
  taint from traced roots).
* **RL002 compile-site-registry** — every ``jit``/``pallas_call``/
  ``lax.scan`` callsite is declared in ``compile_sites.toml`` with its
  expected trace multiplicity; registry drift vs the code or vs the
  ``TRACE_COUNT`` probe is a finding (registry.py).
* **RL003 host-transfer-smell** — ``jax.device_get`` /
  ``.block_until_ready()`` in hot-loop modules outside the blessed
  fetch points (``[[blessed_transfer]]``), plus ``np.asarray`` /
  array-``__iter__`` over traced values inside traced functions.
* **RL004 scenario-leaf-sync** — Scenario/SimParams fields must match
  the registry inventory: fingerprint knobs == the module literals
  (``FAULT_KNOBS``, and since PR 9 the flow engine's ``FLOW_KNOBS``
  via ``flow_fingerprint_params``), every param validated in
  ``__post_init__`` or exempted with a reason, the schema version
  pinned on both sides, no dead Scenario leaves.
* **RL005 prng-discipline** — a key feeding two sampling calls without
  an intervening ``split``/``fold_in`` (checkers.py).
* **RL006 dtype-discipline** — float64 literals/dtypes in bit-exact
  kernel/ref/gating modules.

PR 8 adds the **compiled-artifact layer** (artifact.py): every
registered compile site is AOT-lowered with representative hull shapes
and the optimized HLO is checked against the committed contract file
``artifact_contracts.toml``:

* **RL007 artifact-contract-drift** — fold-buffer dtype under both
  x64 modes, ``memory_analysis()`` peak vs the per-case byte budget,
  ``cost_analysis()`` flops/bytes vs the blessed per-mode bands, full
  registry coverage (every RL002 site audited or skipped with a
  reason), and the planner-calibration spread (the hand cost model
  ``core/planner.py::site_cost`` vs measured flops must stay
  shape-proportional; the same measurements back the opt-in
  ``plan_sites(cost_model="hlo")``).
* **RL008 artifact-collective-callback** — collectives outside the
  per-unit allow-list (on the sharded scenario axis the chunk program
  must contain none) and any host round-trip in the compiled program:
  ``infeed``/``outfeed``/``send``/``recv`` or callback custom-calls.
* **RL009 donation-aliasing-loss** — donated sweep carries must
  actually be input-output aliased in the compiled artifact (probed
  with forced donation on CPU, where the runner itself skips
  ``donate_argnames``).

Workflow: ``python -m repro.analysis --check`` (CI lint-canary; the
artifact-canary job repeats it under ``JAX_ENABLE_X64`` 0/1 and a
4-fake-device sharded config); ``--json``/``--dead-code`` write
reports under ``results/``. The audit runs whenever the contract file
exists (``--no-artifacts`` skips it; ``--bless-artifacts`` re-measures
the per-mode bands — budgets and allow-lists stay reviewed edits). To
bless a lint violation, either register it (compile site, blessed
transfer, validation exemption — all reviewed registry edits) or
annotate the line with ``# repro-lint: disable=RULE(reason)``; reasons
are mandatory and the total suppression count is baselined by
``max_suppressions`` (it can only go down silently, never up).

Runtime cross-validation lives in sanitizer.py: a conftest fixture
arms ``jax.transfer_guard_device_to_host("disallow")`` and a
``jax.log_compiles`` recompile detector around the sweep tests,
asserting the planner pipeline's one-trace-per-bucket contract with
per-hull attribution (the ``TRACE_HOOK`` seam in simulator.py).
"""
from .artifact import ARTIFACT_RELPATH, run_audit  # noqa: F401
from .engine import LintReport, run_lint          # noqa: F401
from .findings import Finding, RULES              # noqa: F401
from .registry import load_config                 # noqa: F401
