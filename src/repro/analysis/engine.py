"""Lint orchestration: scope -> project -> checkers -> suppressions.

`run_lint` is the single entry the CLI and the tests share. It returns
a :class:`LintReport` whose ``findings`` carry their suppression state
(a suppressed finding stays in the report — the JSON artifact is the
audit trail — but does not fail ``--check``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from pathlib import Path

from . import checkers, registry, taint
from .astutil import load_project
from .findings import Finding, apply_suppressions, scan_suppressions
from .registry import REGISTRY_RELPATH, Config


@dataclass
class LintReport:
    findings: list = field(default_factory=list)
    suppression_count: int = 0
    baseline: int = 0
    files: list = field(default_factory=list)

    @property
    def unsuppressed(self) -> list:
        return [f for f in self.findings if not f.suppressed]

    def by_rule(self) -> dict:
        out: dict = {}
        for f in self.findings:
            out.setdefault(f.rule, []).append(f)
        return out

    def to_json(self) -> dict:
        return {
            "files": self.files,
            "n_findings": len(self.findings),
            "n_unsuppressed": len(self.unsuppressed),
            "suppressions": {"count": self.suppression_count,
                             "baseline": self.baseline},
            "findings": [f.to_json() for f in self.findings],
        }


def lint_paths(root: Path, cfg: Config, paths=None) -> list:
    """Resolve the lint scope to concrete .py files."""
    scopes = paths if paths else cfg.lint_scope
    files = []
    for s in scopes:
        p = root / s
        if p.is_dir():
            files.extend(sorted(p.rglob("*.py")))
        elif p.is_file():
            files.append(p)
    return [f for f in files
            if not cfg.is_exempt(f.relative_to(root).as_posix())]


def run_lint(root: Path, cfg: Config, paths=None) -> LintReport:
    files = lint_paths(root, cfg, paths)
    proj = load_project(root, files)
    rep = LintReport(baseline=cfg.max_suppressions,
                     files=[m.path for m in proj.modules.values()])

    findings = []
    findings += taint.analyze(proj).findings
    for mi in proj.modules.values():
        if mi.path in cfg.hot_modules:
            findings += checkers.check_host_transfers(
                mi, cfg.blessed(mi.path))
        if mi.path in cfg.bitexact_modules:
            findings += checkers.check_dtypes(mi)
        findings += checkers.check_prng(mi)
    findings += registry.check_registry(proj, cfg)
    findings += registry.check_scenario_contract(proj, cfg)

    # suppressions: per-file inline annotations, then the global
    # count-only-goes-down baseline
    total = 0
    by_path = {}
    for mi in proj.modules.values():
        sup = scan_suppressions(mi.path, mi.source)
        by_path[mi.path] = sup
        total += sup.count
        findings += sup.bad
    out = []
    for f in findings:
        sup = by_path.get(f.path)
        out += apply_suppressions([f], sup) if sup else [f]
    if total > cfg.max_suppressions:
        out.append(Finding(
            "RL000", REGISTRY_RELPATH, 1,
            f"suppression count {total} exceeds the committed baseline "
            f"{cfg.max_suppressions} — the baseline only goes down "
            "silently; raising it is a reviewed registry edit"))
    rep.findings = sorted(out, key=lambda f: (f.path, f.line, f.rule))
    rep.suppression_count = total
    return rep
