"""Minimal TOML-subset reader for the analysis contract files.

The container pins Python 3.10 (no stdlib ``tomllib``) and the repo
must not grow third-party deps, so the checked-in contract files
(``compile_sites.toml``, ``artifact_contracts.toml``) are restricted to
the subset this reader supports:

* ``[table]`` and ``[[array-of-tables]]`` headers, including dotted
  paths (``[a.b.c]``, ``[[a.b]]``) with standard TOML relative-path
  semantics: an intermediate segment that names an array of tables
  resolves to its LAST element, so ``[[artifact.unit]]`` followed by
  ``[artifact.unit.measured]`` nests the sub-table under the unit just
  declared;
* ``key = value`` pairs with string (basic, double-quoted), integer,
  float, boolean and flat-array values;
* full-line and trailing ``#`` comments.

That subset is exactly what a declarative contract file needs; anything
fancier in the registry is a smell, so the parser raising on unknown
syntax is a feature. The analyzer's own tests round-trip the shipped
registry through this reader.
"""
from __future__ import annotations


class TomlError(ValueError):
    """Raised on syntax outside the supported TOML subset."""


def _parse_scalar(tok: str, where: str):
    tok = tok.strip()
    if not tok:
        raise TomlError(f"{where}: empty value")
    if tok.startswith('"'):
        if not tok.endswith('"') or len(tok) < 2:
            raise TomlError(f"{where}: unterminated string {tok!r}")
        body = tok[1:-1]
        # the only escapes the registry needs
        return (body.replace('\\"', '"').replace("\\\\", "\\")
                .replace("\\n", "\n").replace("\\t", "\t"))
    if tok in ("true", "false"):
        return tok == "true"
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise TomlError(f"{where}: unsupported value {tok!r}") from None


def _split_array(body: str, where: str) -> list:
    """Split a flat-array body on commas outside strings."""
    items, cur, in_str, prev = [], [], False, ""
    for ch in body:
        if ch == '"' and prev != "\\":
            in_str = not in_str
        if ch == "," and not in_str:
            items.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
        prev = ch
    if in_str:
        raise TomlError(f"{where}: unterminated string in array")
    items.append("".join(cur))
    return [_parse_scalar(t, where) for t in items if t.strip()]


def _strip_comment(line: str) -> str:
    out, in_str, prev = [], False, ""
    for ch in line:
        if ch == '"' and prev != "\\":
            in_str = not in_str
        if ch == "#" and not in_str:
            break
        out.append(ch)
        prev = ch
    return "".join(out).strip()


def _descend(root: dict, parts: list, where: str) -> dict:
    """Walk a dotted header path, creating intermediate tables. A
    segment that resolves to an array of tables continues into its
    last element (standard TOML array-of-tables nesting)."""
    cur = root
    for p in parts:
        nxt = cur.setdefault(p, {})
        if isinstance(nxt, list):
            if not nxt:
                raise TomlError(f"{where}: {p} is an empty table array")
            nxt = nxt[-1]
        if not isinstance(nxt, dict):
            raise TomlError(f"{where}: {p} is not a table")
        cur = nxt
    return cur


def _split_path(name: str, where: str) -> list:
    parts = [p.strip() for p in name.split(".")]
    if not all(parts):
        raise TomlError(f"{where}: bad dotted header {name!r}")
    return parts


def loads(text: str) -> dict:
    """Parse the supported TOML subset into nested dicts/lists."""
    root: dict = {}
    table = root
    pending_key = None     # multi-line array accumulation
    pending_val: list[str] = []
    for ln, raw in enumerate(text.splitlines(), 1):
        where = f"line {ln}"
        line = _strip_comment(raw)
        if pending_key is not None:
            pending_val.append(line)
            joined = " ".join(pending_val)
            if joined.rstrip().endswith("]"):
                body = joined.strip()[1:-1]
                table[pending_key] = _split_array(body, where)
                pending_key, pending_val = None, []
            continue
        if not line:
            continue
        if line.startswith("[["):
            if not line.endswith("]]"):
                raise TomlError(f"{where}: bad table-array header")
            parts = _split_path(line[2:-2].strip(), where)
            parent = _descend(root, parts[:-1], where)
            arr = parent.setdefault(parts[-1], [])
            if not isinstance(arr, list):
                raise TomlError(
                    f"{where}: {parts[-1]} is not a table array")
            table = {}
            arr.append(table)
            continue
        if line.startswith("["):
            if not line.endswith("]"):
                raise TomlError(f"{where}: bad table header")
            parts = _split_path(line[1:-1].strip(), where)
            parent = _descend(root, parts[:-1], where)
            table = parent.setdefault(parts[-1], {})
            if not isinstance(table, dict):
                raise TomlError(
                    f"{where}: {parts[-1]} redefined as table")
            continue
        if "=" not in line:
            raise TomlError(f"{where}: expected key = value, got {line!r}")
        key, _, val = line.partition("=")
        key, val = key.strip(), val.strip()
        if not key:
            raise TomlError(f"{where}: empty key")
        if val.startswith("["):
            if val.endswith("]"):
                table[key] = _split_array(val[1:-1], where)
            else:                      # array continued on later lines
                pending_key, pending_val = key, [val]
            continue
        table[key] = _parse_scalar(val, where)
    if pending_key is not None:
        raise TomlError(f"unterminated array for key {pending_key!r}")
    return root


def load(path) -> dict:
    from pathlib import Path
    return loads(Path(path).read_text())
