"""Compiled-artifact contract auditor (rules RL007-RL009).

The source-level rules (RL001-RL006) prove properties of the *Python*
tree; every contract that actually determines the engine's claimed
efficiency lives in the *compiled* artifact: one fused chunk program
with no hidden host round-trips, a scenario batch axis that shards
without resharding, donated carries that are really input-output
aliased, a fold buffer whose dtype follows the x64 mode, a bounded
device-memory footprint. This module AOT-lowers every registered
compile site (the RL002 registry) on representative hull shapes —
through the SAME seams the engine executes (``_prepare_sweep_args`` +
``_sweep_runner``, ``_sim_program``, ``_reactive_program``) — and
checks the compiled artifact against the committed contracts in
``artifact_contracts.toml``:

* **RL008** — collective inventory (analysis/hlo.py ``parse_collectives``)
  must stay inside the unit's allow-list (empty for the chunk program:
  scenarios are independent lanes, sharding them must not introduce
  all-gather/all-reduce/reshard traffic), and the program must contain
  no host callbacks / infeed / outfeed / send / recv.
* **RL009** — donation verification: off-CPU the runner's donated
  carries must be aliased (``memory_analysis().alias_size_in_bytes``);
  on CPU — where the runner deliberately omits ``donate_argnames`` — a
  forced-donation probe compiles the same program WITH donation and
  requires full aliasing, so a carry-structure drift that would break
  donation on an accelerator is caught on the CPU CI.
* **RL007** — contract drift: fold-buffer dtype under the current x64
  mode, peak-device-memory budget, measured ``cost_analysis()``
  FLOPs/bytes vs the blessed per-mode bands, registry coverage (every
  RL002 compile site maps to an audit unit or an ``[[artifact.skip]]``
  with a reason), and the planner cost-model calibration (the
  model-vs-measured ratio must stay within ``max_ratio_spread`` across
  hulls — see ``calibration`` in the payload and
  ``planner.plan_sites(cost_model="hlo")``).

Bless workflow: ``python -m repro.analysis --bless-artifacts`` measures
the current tree and rewrites the contract file's per-mode measured
tables (budgets are only filled when missing, never tightened
silently); RL008/RL009 violations are never blessable. The text-level
checkers are pure functions over HLO text / stat dicts so the fixture
corpus (tests/test_artifact.py) can pin rule IDs without compiling.
"""
from __future__ import annotations

from pathlib import Path

from . import hlo, toml_lite
from .findings import Finding

ARTIFACT_RELPATH = "src/repro/analysis/artifact_contracts.toml"

#: bump when the contract schema or the audit semantics change
ARTIFACT_SCHEMA_VERSION = 1


def load_contracts(root: Path, path: Path | None = None) -> dict:
    p = path or (Path(root) / ARTIFACT_RELPATH)
    return toml_lite.load(p)


def _mode_key() -> str:
    import jax
    return "x64" if jax.config.jax_enable_x64 else "x32"


# ---------------------------------------------------------------------------
# text/stat-level checkers (pure: the fixture corpus drives these)
# ---------------------------------------------------------------------------

def check_collectives_text(hlo_text: str, allowed, path: str,
                           where: str) -> list:
    """RL008: collective ops outside the allow-list."""
    out = []
    allowed = set(allowed or [])
    for op, d in hlo.parse_collectives(hlo_text).by_op().items():
        if op not in allowed:
            out.append(Finding(
                "RL008", path, 1,
                f"{where}: compiled program contains {d['count']}x "
                f"{op} ({d['link_bytes']:.0f} link-bytes) — the "
                "scenario batch axis must not communicate (independent "
                "lanes); extend the unit's collectives_allowed only "
                "with a reviewed contract edit"))
    return out


def check_host_ops_text(hlo_text: str, path: str, where: str) -> list:
    """RL008: host callbacks / infeed / outfeed / send / recv."""
    ops = hlo.find_host_ops(hlo_text)
    if not ops:
        return []
    uniq = sorted(set(ops))
    return [Finding(
        "RL008", path, 1,
        f"{where}: compiled program contains host-communication op(s) "
        f"{uniq} ({len(ops)} total) — the chunk program must stay "
        "device-resident (no io_callback/debug.print/infeed/outfeed)")]


def check_donation(mem: dict, alias_entries: int, donated_bytes: int,
                   min_alias_frac: float, path: str, where: str) -> list:
    """RL009: donated carries must be input-output aliased."""
    alias = int(mem.get("alias_size_in_bytes", 0))
    if donated_bytes <= 0:
        return []
    if alias >= min_alias_frac * donated_bytes and alias_entries > 0:
        return []
    return [Finding(
        "RL009", path, 1,
        f"{where}: donation lost — {alias}/{donated_bytes} donated "
        f"carry bytes aliased ({alias_entries} alias entries, need "
        f">= {min_alias_frac:.0%}); a carry input/output structure or "
        "dtype mismatch is blocking XLA buffer donation")]


def check_fold_dtype(found: str, expected: str, path: str,
                     where: str) -> list:
    """RL007: the fold-buffer dtype must follow the x64 mode."""
    if found == expected:
        return []
    return [Finding(
        "RL007", path, 1,
        f"{where}: fold buffer dtype is {found}, contract expects "
        f"{expected} for this x64 mode — the Kahan fold precision "
        "contract (_fold_dtype) drifted")]


def check_memory_budget(mem: dict, budget: int, path: str,
                        where: str) -> list:
    """RL007: peak device memory (temp + output) within budget."""
    peak = int(mem.get("temp_size_in_bytes", 0)) \
        + int(mem.get("output_size_in_bytes", 0))
    if budget and peak > budget:
        return [Finding(
            "RL007", path, 1,
            f"{where}: peak device memory {peak} B exceeds the "
            f"contract budget {budget} B (temp "
            f"{mem.get('temp_size_in_bytes', 0)} + output "
            f"{mem.get('output_size_in_bytes', 0)}); re-bless only "
            "after reviewing what grew")]
    return []


def check_cost_drift(measured: dict, blessed: dict | None, rtol: float,
                     mode: str, path: str, where: str) -> list:
    """RL007: measured cost_analysis() vs the blessed per-mode band."""
    if blessed is None:
        return [Finding(
            "RL007", path, 1,
            f"{where}: no blessed {mode} measurement in "
            "artifact_contracts.toml — run `python -m repro.analysis "
            "--bless-artifacts` under this mode and commit the "
            "contract update")]
    out = []
    for key, label in (("flops_per_scen", "FLOPs"),
                       ("bytes_per_scen", "bytes-accessed")):
        m, b = measured.get(key), blessed.get(key)
        if not b:
            continue
        if abs(m - b) > rtol * b:
            out.append(Finding(
                "RL007", path, 1,
                f"{where}: measured {label} {m:.0f} drifted beyond "
                f"±{rtol:.0%} of the blessed {b:.0f} ({mode}) — the "
                "compiled cost moved; review and re-bless"))
    return out


def check_coverage(cfg, art: dict) -> list:
    """RL007: every RL002 compile site maps to an audit unit's covers
    list or an [[artifact.skip]] entry with a reason."""
    out = []
    covers = []
    for u in art.get("unit", []):
        covers.extend(u.get("covers", []))
    skips = {}
    for s in art.get("skip", []):
        key = f"{s.get('file', '')}::{s.get('qualname', '')}"
        skips[key] = s
        if not str(s.get("reason", "")).strip():
            out.append(Finding(
                "RL007", ARTIFACT_RELPATH, 1,
                f"artifact.skip entry {key} carries no reason"))
    for e in cfg.raw.get("compile_site", []):
        key = f"{e.get('file', '')}::{e.get('qualname', '')}"
        covered = key in skips or any(
            key == c or key.startswith(c + ".") for c in covers)
        if not covered:
            out.append(Finding(
                "RL007", ARTIFACT_RELPATH, 1,
                f"registry compile site {key} is not covered by any "
                "artifact audit unit — add it to a unit's covers list "
                "or declare an [[artifact.skip]] with a reason"))
    return out


def check_calibration(cal: dict, max_spread: float) -> list:
    """RL007: the hand cost model must track measured HLO cost — the
    per-hull model-vs-measured ratio spread stays bounded."""
    spread = cal.get("ratio_spread", 1.0)
    if spread <= max_spread:
        return []
    hulls = ", ".join(f"{h['tag']}:{h['ratio']:.1f}"
                      for h in cal.get("hulls", []))
    return [Finding(
        "RL007", "src/repro/core/planner.py", 1,
        f"planner cost-model calibration: model-vs-measured ratio "
        f"spread {spread:.2f} exceeds max_ratio_spread {max_spread} "
        f"across hulls ({hulls}) — site_cost mis-scales with hull "
        "size and would mis-bucket sweeps; recalibrate the footprint "
        "model or switch the sweep to cost_model='hlo'")]


# ---------------------------------------------------------------------------
# unit builders (lazy jax: lint-only runs never import it)
# ---------------------------------------------------------------------------

def _case_site(case: dict):
    from repro.core.topology import FBSite
    return FBSite(n_clusters=int(case["ncl"]),
                  racks_per_cluster=int(case["rpc"]),
                  servers_per_rack=int(case["spr"]),
                  csw_per_cluster=int(case["cpc"]),
                  n_fc=int(case["nfc"]))


def _tree_nbytes(tree) -> int:
    import jax
    import numpy as np
    return int(sum(np.prod(a.shape) * a.dtype.itemsize
                   for a in jax.tree.leaves(tree)))


def _audit_sweep_case(unit: dict, case: dict, art: dict, mode: str,
                      bless: bool):
    import jax
    import jax.numpy as jnp

    from repro.core import simulator as S
    from repro.core.traffic import TRAFFIC_SPECS

    site = _case_site(case)
    n = int(case.get("n_scenarios", 2))
    length = int(case.get("length", 16))
    validate = bool(case.get("validate", False))
    spec = TRAFFIC_SPECS[case.get("spec", "fb_hadoop")]
    runs = [(S.SimParams(spec=spec, site=site), i) for i in range(n)]
    batch = S.make_batch(runs)
    hull = batch.hull
    scen, state, fold, guard, tol = S._prepare_sweep_args(
        batch, fold="device", validate=validate)
    live = jnp.ones((length,), bool)
    ci = jnp.asarray(0, jnp.int32) if validate else None

    runner = S._sweep_runner()
    comp = runner.lower(hull, scen, state, length, live, fold, guard,
                        ci, tol, validate).compile()
    txt = comp.as_text()
    mem = hlo.memory_stats(comp)
    cost = hlo.cost_stats(comp)

    # per-scenario normalization keeps the measurement leg-invariant:
    # under scenario-axis sharding cost_analysis() reports one shard
    n_padded = int(jax.tree.leaves(scen)[0].shape[0])
    n_shards = jax.local_device_count() \
        if S._should_shard(len(batch), None) else 1
    per_scen = max(n_padded // n_shards, 1)
    measured = {
        "flops_per_scen": cost["flops"] / per_scen,
        "bytes_per_scen": cost["bytes_accessed"] / per_scen,
    }

    path, where = unit.get("file", ARTIFACT_RELPATH), \
        f"sweep_chunk[{case.get('tag', '?')}{'+validate' if validate else ''}]"
    findings = []
    findings += check_collectives_text(
        txt, unit.get("collectives_allowed", []), path, where)
    findings += check_host_ops_text(txt, path, where)
    findings += check_memory_budget(
        mem, int(case.get("peak_bytes_budget", 0)), ARTIFACT_RELPATH,
        where)

    # fold dtype under the current x64 mode (base, non-validate cases)
    fold_dtype = None
    if not validate:
        out_shape = jax.eval_shape(
            lambda sc, st, fo: S._sweep_chunk_impl(
                hull, sc, st, length, live, fo, None, None, None,
                False),
            scen, state, fold)
        dts = {str(a.dtype) for a in jax.tree.leaves(out_shape[1])}
        fold_dtype = sorted(dts)[0] if len(dts) == 1 else str(sorted(dts))
        expected = unit.get(f"fold_dtype_{mode}",
                            "float64" if mode == "x64" else "float32")
        findings += check_fold_dtype(fold_dtype, expected, path, where)

    # donation: off-CPU the real runner must alias; on CPU force it
    # through a probe so carry-structure drift is caught before TPU
    donation = unit.get("donation", "none")
    alias_info = None
    if donation == "off-cpu" and bool(case.get("donation_probe", False)):
        donated = _tree_nbytes(state) + _tree_nbytes(fold)
        min_frac = float(art.get("min_alias_frac", 1.0))
        if jax.default_backend() == "cpu":
            # structural probe, unsharded leg only: under scenario-axis
            # sharding memory_analysis() reports per-shard alias sizes
            # against whole-array donated bytes, so the 100% fraction
            # cannot be stated; the 1-device canary leg pins it
            if n_shards == 1:
                probe = jax.jit(S._sweep_chunk_impl,
                                static_argnames=("site", "length",
                                                 "validate"),
                                donate_argnames=("state", "fold"))
                pcomp = probe.lower(hull, scen, state, length, live,
                                    fold, guard, ci, tol,
                                    validate).compile()
                pmem = hlo.memory_stats(pcomp)
                entries = hlo.count_alias_entries(pcomp.as_text())
                findings += check_donation(
                    pmem, entries, donated, min_frac, path,
                    where + "+donation-probe")
                alias_info = {"probe": True,
                              "alias_size": pmem["alias_size_in_bytes"],
                              "entries": entries,
                              "donated_bytes": donated}
        else:
            entries = hlo.count_alias_entries(txt)
            findings += check_donation(mem, entries, donated, min_frac,
                                       path, where)
            alias_info = {"probe": False,
                          "alias_size": mem["alias_size_in_bytes"],
                          "entries": entries, "donated_bytes": donated}

    if not bless:
        blessed = case.get("measured", {}).get(mode)
        findings += check_cost_drift(
            measured, blessed, float(art.get("cost_rtol", 0.5)), mode,
            ARTIFACT_RELPATH, where)

    payload = {"tag": case.get("tag"), "validate": validate,
               "measured": measured, "memory": mem,
               "collectives": hlo.parse_collectives(txt).by_op(),
               "host_ops": len(hlo.find_host_ops(txt)),
               "fold_dtype": fold_dtype, "alias": alias_info,
               "n_scenarios": n, "length": length,
               "shards": n_shards}
    if bless:
        case.setdefault("measured", {})[mode] = {
            k: round(v, 1) for k, v in measured.items()}
        if not case.get("peak_bytes_budget"):
            peak = mem["temp_size_in_bytes"] + mem["output_size_in_bytes"]
            case["peak_bytes_budget"] = 4 * peak
    return findings, payload


def _audit_run_sim_case(unit: dict, case: dict, art: dict, mode: str,
                        bless: bool):
    import jax

    from repro.core import simulator as S
    from repro.core.traffic import TRAFFIC_SPECS

    site = _case_site(case)
    n_ticks = int(case.get("n_ticks", 64))
    spec = TRAFFIC_SPECS[case.get("spec", "fb_hadoop")]
    params = S.SimParams(spec=spec, site=site)
    batch = S.make_batch([(params, 0)])
    hull = batch.hull
    scen = jax.tree.map(lambda x: x[0], batch.scen)
    state = S._init_state(hull, scen, jax.random.PRNGKey(0))
    go = S._sim_program(hull, scen, n_ticks)
    comp = go.lower(state).compile()
    txt = comp.as_text()
    mem = hlo.memory_stats(comp)
    cost = hlo.cost_stats(comp)
    measured = {"flops_per_scen": cost["flops"],
                "bytes_per_scen": cost["bytes_accessed"]}

    path = unit.get("file", ARTIFACT_RELPATH)
    where = f"run_sim[{case.get('tag', '?')}]"
    findings = []
    findings += check_collectives_text(
        txt, unit.get("collectives_allowed", []), path, where)
    findings += check_host_ops_text(txt, path, where)
    findings += check_memory_budget(
        mem, int(case.get("peak_bytes_budget", 0)), ARTIFACT_RELPATH,
        where)
    if not bless:
        findings += check_cost_drift(
            measured, case.get("measured", {}).get(mode),
            float(art.get("cost_rtol", 0.5)), mode, ARTIFACT_RELPATH,
            where)
    payload = {"tag": case.get("tag"), "measured": measured,
               "memory": mem,
               "collectives": hlo.parse_collectives(txt).by_op(),
               "host_ops": len(hlo.find_host_ops(txt))}
    if bless:
        case.setdefault("measured", {})[mode] = {
            k: round(v, 1) for k, v in measured.items()}
        if not case.get("peak_bytes_budget"):
            peak = mem["temp_size_in_bytes"] + mem["output_size_in_bytes"]
            case["peak_bytes_budget"] = 4 * peak
    return findings, payload


def _audit_ici_case(unit: dict, case: dict, art: dict, mode: str,
                    bless: bool):
    import numpy as np

    from repro.core import constants as C
    from repro.core import ici_gating

    n_ticks = int(case.get("n_ticks", 256))
    tick_us = float(case.get("tick_us", 1.0))
    links = C.TPU_ICI_LINKS_PER_CHIP
    bw_link_tick = C.TPU_ICI_LINK_BW * 1e-6 * tick_us
    cap_q = 8 * bw_link_tick
    up_delay = max(int(np.ceil(C.LASER_ON_US / tick_us)), 1)
    run = ici_gating._reactive_program(links, bw_link_tick, tick_us,
                                       cap_q, up_delay)
    comp = run.lower(np.zeros(n_ticks)).compile()
    txt = comp.as_text()
    mem = hlo.memory_stats(comp)
    cost = hlo.cost_stats(comp)
    measured = {"flops_per_scen": cost["flops"],
                "bytes_per_scen": cost["bytes_accessed"]}

    path = unit.get("file", ARTIFACT_RELPATH)
    where = f"ici_reactive[{case.get('tag', '?')}]"
    findings = []
    findings += check_collectives_text(
        txt, unit.get("collectives_allowed", []), path, where)
    findings += check_host_ops_text(txt, path, where)
    findings += check_memory_budget(
        mem, int(case.get("peak_bytes_budget", 0)), ARTIFACT_RELPATH,
        where)
    if not bless:
        findings += check_cost_drift(
            measured, case.get("measured", {}).get(mode),
            float(art.get("cost_rtol", 0.5)), mode, ARTIFACT_RELPATH,
            where)
    payload = {"tag": case.get("tag"), "measured": measured,
               "memory": mem,
               "collectives": hlo.parse_collectives(txt).by_op(),
               "host_ops": len(hlo.find_host_ops(txt))}
    if bless:
        case.setdefault("measured", {})[mode] = {
            k: round(v, 1) for k, v in measured.items()}
        if not case.get("peak_bytes_budget"):
            peak = mem["temp_size_in_bytes"] + mem["output_size_in_bytes"]
            case["peak_bytes_budget"] = 4 * peak
    return findings, payload


_BUILDERS = {
    "sweep_chunk": _audit_sweep_case,
    "run_sim": _audit_run_sim_case,
    "ici_reactive": _audit_ici_case,
}


# ---------------------------------------------------------------------------
# planner cost-model calibration
# ---------------------------------------------------------------------------

def calibration(art: dict, unit_payloads: dict) -> dict:
    """Model-vs-measured cost per audited hull: ratio = measured HLO
    FLOPs per (scenario, tick) over ``planner.site_cost`` units. Only
    RATIOS matter for bucketing, so the hand model is healthy iff the
    ratio is stable across hulls (``ratio_spread`` = max/min).

    Each hull also reports its arithmetic intensity (HLO FLOPs /
    bytes-accessed) against the TPU ridge point (peak FLOPs / HBM BW,
    the benchmarks/roofline.py constants): ``site_cost`` models the
    step as bandwidth-bound elementwise work, and ``ridge_frac`` << 1
    is that premise made measurable."""
    from repro.core import constants as C
    from repro.core import planner
    from repro.core.topology import site_tag

    ridge = C.TPU_PEAK_BF16_FLOPS / C.TPU_HBM_BW
    hulls = []
    for u in art.get("unit", []):
        if u.get("builder") != "sweep_chunk":
            continue
        pays = unit_payloads.get(u.get("name"), {}).get("cases", [])
        by_tag = {p.get("tag"): p for p in pays}
        for case in u.get("case", []):
            if case.get("validate"):
                continue                   # guard math skews the ratio
            p = by_tag.get(case.get("tag"))
            if not p:
                continue
            site = _case_site(case)
            model = planner.site_cost(site)
            meas = p["measured"]["flops_per_scen"] / max(
                int(case.get("length", 16)), 1)
            intensity = p["measured"]["flops_per_scen"] / max(
                p["measured"]["bytes_per_scen"], 1e-12)
            hulls.append({"tag": site_tag(site), "model_cost": model,
                          "measured_flops_per_tick_scen": meas,
                          "ratio": meas / max(model, 1e-12),
                          "arith_intensity": intensity,
                          "ridge_frac": intensity / ridge})
    ratios = [h["ratio"] for h in hulls]
    spread = (max(ratios) / max(min(ratios), 1e-12)) if ratios else 1.0
    import math
    k = math.exp(sum(math.log(max(r, 1e-12)) for r in ratios)
                 / len(ratios)) if ratios else 1.0
    return {"hulls": hulls, "ratio_spread": spread,
            "mean_ratio": k}


# ---------------------------------------------------------------------------
# contract file emitter (the --bless-artifacts writer)
# ---------------------------------------------------------------------------

_HEADER = """\
# Compiled-artifact contracts for repro.analysis.artifact (RL007-RL009;
# see ROADMAP "Static contracts"). Measured tables are per x64 mode and
# written by `python -m repro.analysis --bless-artifacts` — regenerable
# audit JSON lives under results/ (gitignored), ONLY this blessed file
# is committed. Budgets and allow-lists are reviewed edits: blessing
# never tightens a budget and never blesses a collective/callback in.
"""


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if isinstance(v, str):
        return '"' + v.replace("\\", "\\\\").replace('"', '\\"') + '"'
    if isinstance(v, float):
        return repr(v)
    if isinstance(v, int):
        return str(v)
    if isinstance(v, list):
        return "[" + ", ".join(_fmt(x) for x in v) + "]"
    raise TypeError(f"cannot emit {type(v).__name__} in contracts file")


def _emit_pairs(d: dict, lines: list):
    for k, v in d.items():
        if isinstance(v, dict):
            continue                      # sub-tables emit their own header
        if isinstance(v, list) and v and isinstance(v[0], dict):
            continue                      # arrays-of-tables likewise
        lines.append(f"{k} = {_fmt(v)}")


def dump_contracts(contracts: dict) -> str:
    art = contracts.get("artifact", {})
    lines = [_HEADER, "[artifact]"]
    _emit_pairs(art, lines)
    for s in art.get("skip", []):
        lines += ["", "[[artifact.skip]]"]
        _emit_pairs(s, lines)
    for u in art.get("unit", []):
        lines += ["", "[[artifact.unit]]"]
        _emit_pairs(u, lines)
        for c in u.get("case", []):
            lines += ["", "[[artifact.unit.case]]"]
            _emit_pairs(c, lines)
            meas = c.get("measured", {})
            for mode in sorted(meas):
                lines += ["", f"[artifact.unit.case.measured.{mode}]"]
                _emit_pairs(meas[mode], lines)
    return "\n".join(lines) + "\n"


# ---------------------------------------------------------------------------
# the audit driver
# ---------------------------------------------------------------------------

def run_audit(root: Path, cfg, contracts_path: Path | None = None, *,
              bless: bool = False, units=None):
    """Audit every contract unit; returns ``(findings, payload)``.

    With ``bless=True`` the per-mode measured tables (and missing
    budgets) are rewritten in place; drift checks are skipped (a fresh
    bless is definitionally in-band) but RL008/RL009 violations still
    fire — collectives, callbacks and donation loss are never
    blessable. ``units`` restricts the audit to the named units (the
    coverage check is skipped for partial audits).
    """
    import jax

    root = Path(root)
    cpath = Path(contracts_path) if contracts_path \
        else root / ARTIFACT_RELPATH
    contracts = toml_lite.load(cpath)
    art = contracts.get("artifact", {})
    mode = _mode_key()

    findings = []
    if int(art.get("schema_version", 0)) != ARTIFACT_SCHEMA_VERSION:
        findings.append(Finding(
            "RL007", ARTIFACT_RELPATH, 1,
            f"artifact contract schema_version "
            f"{art.get('schema_version')} != auditor "
            f"{ARTIFACT_SCHEMA_VERSION} (bump both together)"))
    if units is None:
        findings += check_coverage(cfg, art)

    unit_payloads = {}
    for u in art.get("unit", []):
        name = u.get("name", "?")
        if units is not None and name not in units:
            continue
        builder = _BUILDERS.get(u.get("builder", ""))
        if builder is None:
            findings.append(Finding(
                "RL007", ARTIFACT_RELPATH, 1,
                f"artifact unit {name!r} names unknown builder "
                f"{u.get('builder')!r} (known: "
                f"{sorted(_BUILDERS)})"))
            continue
        cases = []
        for case in u.get("case", []):
            f, p = builder(u, case, art, mode, bless)
            findings += f
            cases.append(p)
        unit_payloads[name] = {"builder": u.get("builder"),
                               "cases": cases}

    cal = calibration(art, unit_payloads)
    if cal["hulls"]:
        findings += check_calibration(
            cal, float(art.get("max_ratio_spread", 2.0)))

    if bless:
        cpath.write_text(dump_contracts(contracts))

    payload = {
        "schema_version": ARTIFACT_SCHEMA_VERSION,
        "mode": {"x64": mode == "x64",
                 "backend": jax.default_backend(),
                 "devices": jax.local_device_count(),
                 "jax": jax.__version__},
        "blessed": bless,
        "units": unit_payloads,
        "calibration": cal,
    }
    return findings, payload


def hlo_cost_table(root: Path | None = None,
                   contracts_path: Path | None = None,
                   mode: str = "x32") -> dict:
    """Blessed per-hull cost table for ``planner.plan_sites(
    cost_model="hlo")``: ``full_site_tag -> {"flops_per_tick_scen",
    "site"}``. Reads only the committed contract file (no jax), so the
    planner stays importable without an accelerator stack."""
    from repro.core.topology import full_site_tag

    if contracts_path is None:
        base = Path(root) if root is not None \
            else Path(__file__).resolve().parents[3]
        contracts_path = base / ARTIFACT_RELPATH
    art = toml_lite.load(contracts_path).get("artifact", {})
    table = {}
    for u in art.get("unit", []):
        if u.get("builder") != "sweep_chunk":
            continue
        for case in u.get("case", []):
            if case.get("validate"):
                continue
            blessed = case.get("measured", {}).get(mode)
            if not blessed:
                continue
            site = _case_site(case)
            length = max(int(case.get("length", 16)), 1)
            table[full_site_tag(site)] = {
                "flops_per_tick_scen":
                    float(blessed["flops_per_scen"]) / length,
                "site": site,
            }
    return table
