"""``python -m repro.analysis`` — the contract linter + artifact-audit CLI.

Exit status: 0 unless ``--check`` is given and unsuppressed findings
remain (or the registry itself is unreadable). ``--json``/``--dead-code``
write machine-readable reports under ``results/`` for the CI artifact
upload; the compiled-artifact audit (RL007-RL009) runs whenever the
contracts file is present (skip with ``--no-artifacts``; refresh the
blessed measured bands with ``--bless-artifacts``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from . import artifact
from .engine import run_lint
from .findings import RULES
from .reachability import dead_code_report
from .registry import REGISTRY_RELPATH, load_config


def find_root(start: Path | None = None) -> Path:
    """Repo root = nearest ancestor holding the registry; falls back to
    the source checkout this module sits in."""
    cur = (start or Path.cwd()).resolve()
    for p in [cur, *cur.parents]:
        if (p / REGISTRY_RELPATH).is_file():
            return p
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="contract-aware static analysis + compiled-artifact "
                    "audit for the sweep engine (rules RL001-RL009; "
                    "see ROADMAP 'Static contracts')")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: the registry's "
                         "lint_scope)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 if any unsuppressed finding remains")
    ap.add_argument("--json", nargs="?", metavar="PATH",
                    const="results/analysis_report.json", default=None,
                    help="write the machine-readable report "
                         "(default %(const)s)")
    ap.add_argument("--dead-code", action="store_true",
                    help="also emit results/dead_code_report.json "
                         "(module reachability from the bench/"
                         "simulator roots)")
    ap.add_argument("--no-artifacts", action="store_true",
                    help="skip the compiled-artifact audit "
                         "(RL007-RL009): lint only, no jax import")
    ap.add_argument("--bless-artifacts", action="store_true",
                    help="measure the compiled artifacts and rewrite "
                         "the contract file's per-mode blessed bands "
                         "(collective/callback/donation violations "
                         "still fail — they are never blessable)")
    ap.add_argument("--artifact-contracts", default=None, metavar="PATH",
                    help="contracts file to audit against (default "
                         f"{artifact.ARTIFACT_RELPATH})")
    ap.add_argument("--artifact-units", default=None, metavar="NAMES",
                    help="comma-separated subset of audit units to run "
                         "(skips the registry-coverage check)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-finding output")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve() if args.root else find_root()
    try:
        cfg = load_config(root)
    except Exception as e:  # unreadable registry is itself a failure
        print(f"error: cannot load {REGISTRY_RELPATH}: {e}",
              file=sys.stderr)
        return 2

    rep = run_lint(root, cfg, args.paths or None)

    # compiled-artifact audit: on whenever the contracts file exists
    contracts_path = Path(args.artifact_contracts).resolve() \
        if args.artifact_contracts else root / artifact.ARTIFACT_RELPATH
    artifact_payload = None
    if not args.no_artifacts and contracts_path.is_file():
        units = [u.strip() for u in args.artifact_units.split(",")
                 if u.strip()] if args.artifact_units else None
        try:
            afindings, artifact_payload = artifact.run_audit(
                root, cfg, contracts_path,
                bless=args.bless_artifacts, units=units)
        except Exception as e:
            print(f"error: artifact audit failed: {e}", file=sys.stderr)
            return 2
        rep.findings = sorted(
            rep.findings + afindings,
            key=lambda f: (f.path, f.line, f.rule))
    elif args.bless_artifacts:
        print(f"error: no contracts file at {contracts_path}",
              file=sys.stderr)
        return 2

    if not args.quiet:
        for f in rep.findings:
            print(f.format())
    by_rule = rep.by_rule()
    parts = []
    for rule in sorted(by_rule):
        n = len(by_rule[rule])
        ns = sum(1 for f in by_rule[rule] if not f.suppressed)
        parts.append(f"{rule}:{ns}/{n}")
    print(f"repro.analysis: {len(rep.files)} files, "
          f"{len(rep.unsuppressed)} unsuppressed finding(s) "
          f"({', '.join(parts) if parts else 'clean'}), "
          f"{rep.suppression_count}/{rep.baseline} suppressions used")
    if artifact_payload is not None:
        cal = artifact_payload.get("calibration") or {}
        n_cases = sum(len(u.get("cases", []))
                      for u in artifact_payload["units"].values())
        mode = artifact_payload["mode"]
        print(f"artifact audit: {len(artifact_payload['units'])} "
              f"unit(s), {n_cases} case(s) "
              f"[x64={int(mode['x64'])}, {mode['devices']} device(s)], "
              f"planner calibration spread "
              f"{cal.get('ratio_spread', 1.0):.2f}"
              + (" — contracts re-blessed"
                 if artifact_payload.get("blessed") else ""))

    if args.json:
        out = root / args.json
        out.parent.mkdir(parents=True, exist_ok=True)
        payload = rep.to_json()
        payload["rules"] = {r: {"name": n, "invariant": i}
                            for r, (n, i) in RULES.items()}
        if artifact_payload is not None:
            payload["artifact"] = artifact_payload
        out.write_text(json.dumps(payload, indent=2, sort_keys=True))
        try:
            shown = out.relative_to(root)
        except ValueError:            # --json outside the repo root
            shown = out
        print(f"wrote {shown}")

    if args.dead_code:
        dc = dead_code_report(root, cfg.lint_exempt)
        out = root / "results" / "dead_code_report.json"
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(dc, indent=2, sort_keys=True))
        s = dc["summary"]
        n_ex = sum(1 for u in dc["unreachable"] if u["exempt"])
        print(f"dead-code: {s['n_reachable']}/{s['n_modules']} modules "
              f"reachable; {s['n_unreachable']} unreachable "
              f"({n_ex} exempt seed modules, "
              f"{s['loc_unreachable']} LoC) -> "
              f"{out.relative_to(root)}")

    if args.check and rep.unsuppressed:
        return 1
    return 0
