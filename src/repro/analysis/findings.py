"""Finding/suppression model for the repro contract linter.

A finding is one named-rule violation at a file:line. Suppressions are
inline comments of the form::

    some_code()  # repro-lint: disable=RL003(timing barrier), RL006(x)

i.e. ``disable=`` followed by one or more ``RULE(reason)`` entries. The
reason string is MANDATORY — a bare ``disable=RL003`` or an empty
``RL003()`` does not suppress and instead raises an RL000
bad-suppression finding, so every silenced contract carries its
justification in the diff. A suppression on a line silences findings of
that rule on the same line; a suppression comment on its OWN line
silences the next code line (for lines too long to annotate inline).

The committed suppression count is itself a contract: the registry's
``max_suppressions`` baseline can only be lowered silently, never
raised (RL000 fires when the tree carries more suppressions than the
baseline allows).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

#: rule id -> (title, invariant one-liner); the single source the CLI,
#: the ROADMAP section and the fixture tests enumerate
RULES = {
    "RL000": ("bad-suppression",
              "suppressions need a RULE(reason) with a non-empty reason,"
              " and their committed count may only go down"),
    "RL001": ("traced-control-flow",
              "no Python control flow or host coercion (if/while/assert,"
              " float()/int()/bool()/.item()) on traced values inside"
              " jitted/pallas/scan-reachable functions"),
    "RL002": ("compile-site-registry",
              "every jit/pallas_call/lax.scan callsite is declared in"
              " compile_sites.toml with its trace multiplicity, and the"
              " registry tracks the TRACE_COUNT pin"),
    "RL003": ("host-transfer-smell",
              "no device_get/block_until_ready/implicit host transfer in"
              " hot-loop modules outside the blessed fetch points"),
    "RL004": ("scenario-leaf-sync",
              "every Scenario/SimParams knob is registered in the"
              " scenario contract (fingerprint + validation + schema"
              " version) — no silent knob drift"),
    "RL005": ("prng-discipline",
              "a PRNG key feeds at most one sampling call without an"
              " intervening split/fold_in"),
    "RL006": ("dtype-discipline",
              "no float64 literals/dtypes in bit-exact kernel/ref/gating"
              " code (results must not depend on the x64 mode)"),
    "RL007": ("artifact-contract-drift",
              "every registry compile site is covered by an artifact"
              " audit unit (or skipped with a reason) and the compiled"
              " artifact's cost/memory/fold-dtype stays inside the"
              " blessed bands of artifact_contracts.toml (re-bless via"
              " --bless-artifacts)"),
    "RL008": ("artifact-collective-callback",
              "the compiled chunk program carries no collectives on the"
              " scenario batch axis beyond the contract's allow-list"
              " and no host callbacks/infeed/outfeed/send/recv"),
    "RL009": ("donation-aliasing-loss",
              "buffers declared donated are actually input-output"
              " aliased in the compiled artifact off-CPU, and the carry"
              " structure stays fully aliasable (donation-probe) on"
              " CPU"),
}

_SUPPRESS_RE = re.compile(r"#\s*repro-lint:\s*disable=(.*)$")
_ENTRY_RE = re.compile(r"(RL\d{3})\s*(?:\(([^()]*)\))?")


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str            # repo-relative
    line: int
    message: str
    severity: str = "error"
    suppressed: bool = False
    suppress_reason: str = ""

    def format(self) -> str:
        tag = f" [suppressed: {self.suppress_reason}]" \
            if self.suppressed else ""
        return (f"{self.path}:{self.line}: {self.rule}"
                f"({RULES[self.rule][0]}) {self.message}{tag}")

    def to_json(self) -> dict:
        return {"rule": self.rule, "name": RULES[self.rule][0],
                "path": self.path, "line": self.line,
                "message": self.message, "severity": self.severity,
                "suppressed": self.suppressed,
                "suppress_reason": self.suppress_reason}


@dataclass
class Suppressions:
    """Per-file map of line -> {rule: reason} plus the RL000 findings
    malformed suppressions raise."""
    by_line: dict = field(default_factory=dict)
    bad: list = field(default_factory=list)     # Finding (RL000)
    count: int = 0                              # well-formed entries

    def reason_for(self, rule: str, line: int) -> str | None:
        ent = self.by_line.get(line)
        if ent is None:
            return None
        return ent.get(rule)


def scan_suppressions(path: str, source: str) -> Suppressions:
    """Extract ``# repro-lint: disable=...`` comments from a file.

    An annotation on a code line applies to that line; an annotation on
    a comment-only line applies to the NEXT line (so long statements
    can carry their justification above themselves).
    """
    sup = Suppressions()
    for ln, raw in enumerate(source.splitlines(), 1):
        m = _SUPPRESS_RE.search(raw)
        if not m:
            continue
        own_line = raw.lstrip().startswith("#")
        target = ln + 1 if own_line else ln
        body = m.group(1)
        matched_any = False
        for em in _ENTRY_RE.finditer(body):
            matched_any = True
            rule, reason = em.group(1), (em.group(2) or "").strip()
            if rule not in RULES:
                sup.bad.append(Finding(
                    "RL000", path, ln,
                    f"suppression names unknown rule {rule}"))
                continue
            if not reason:
                sup.bad.append(Finding(
                    "RL000", path, ln,
                    f"suppression of {rule} carries no reason string "
                    f"(write {rule}(why it is safe))"))
                continue
            sup.by_line.setdefault(target, {})[rule] = reason
            sup.count += 1
        if not matched_any:
            sup.bad.append(Finding(
                "RL000", path, ln,
                f"malformed repro-lint suppression: {body.strip()!r}"))
    return sup


def apply_suppressions(findings: list, sup: Suppressions) -> list:
    """Mark findings covered by a same-line suppression of their rule."""
    out = []
    for f in findings:
        reason = sup.reason_for(f.rule, f.line)
        if reason is not None and not f.suppressed:
            f = Finding(f.rule, f.path, f.line, f.message, f.severity,
                        suppressed=True, suppress_reason=reason)
        out.append(f)
    return out
