"""Runtime sanitizers: the dynamic half of the contract linter.

The static rules (RL001-RL006) prove properties of the source; these
context managers watch the same contracts at execution time, so the
two cross-validate:

* :func:`transfer_sanitizer` — arms
  ``jax.transfer_guard_device_to_host("disallow")``: implicit
  device->host transfers raise, while the blessed *explicit*
  ``jax.device_get`` fetch points keep working (exactly the RL003
  split). Caveat: on a CPU-only backend host and device share buffers,
  so ``np.asarray(jax_array)`` is zero-copy and the guard cannot trip —
  there the teeth are the HOST_TRANSFER_COUNT pin and the ledgers
  below; on accelerator backends the guard bites for real.

* :class:`CompileWatcher` — ``jax.log_compiles``-based recompile
  detector: captures every XLA "Compiling <name>" event while active,
  so a test can assert a sweep triggered no recompilation beyond its
  declared multiplicity (compile_sites.toml).

* :class:`TraceLedger` — hooks ``simulator.TRACE_HOOK`` to record the
  static ``site`` hull of every sweep-step trace.
  :meth:`SanitizerSession.assert_one_trace_per_bucket` turns that into
  the planner pipeline's contract: under ``pipeline=True`` each plan
  bucket compiles exactly once, and a violation fails with the
  offending bucket's hull tag (not just a drifted total).

Wired into pytest via the ``sweep_sanitizer`` fixture in
tests/conftest.py and exercised by tests/test_sanitizer.py (the CI
lint-canary leg).
"""
from __future__ import annotations

import contextlib
import logging
import re
from collections import Counter
from dataclasses import dataclass

import jax

from repro.core import simulator
from repro.core.topology import full_site_tag

_COMPILE_RE = re.compile(r"Compiling (\S+) with global shapes")
#: the logger jax.log_compiles routes "Compiling <name> ..." through
_COMPILE_LOGGER = "jax._src.interpreters.pxla"


@contextlib.contextmanager
def transfer_sanitizer():
    """Disallow implicit device->host transfers; explicit device_get
    stays legal. Scoped to the device->host direction only: feeding
    numpy scenario tables INTO a jitted sweep is normal dispatch, the
    RL003 contract polices what silently comes back OUT."""
    with jax.transfer_guard_device_to_host("disallow"):
        yield


class CompileWatcher(logging.Handler):
    """Collects XLA compile events (function names) while active."""

    def __init__(self):
        super().__init__(level=logging.WARNING)
        self.events: list = []

    def emit(self, record):
        m = _COMPILE_RE.search(record.getMessage())
        if m:
            self.events.append(m.group(1))

    def compiles_of(self, name: str) -> int:
        return sum(1 for e in self.events if e == name)

    def __enter__(self):
        self._cm = jax.log_compiles()
        self._cm.__enter__()
        logging.getLogger(_COMPILE_LOGGER).addHandler(self)
        return self

    def __exit__(self, *exc):
        logging.getLogger(_COMPILE_LOGGER).removeHandler(self)
        self._cm.__exit__(*exc)
        return False


class TraceLedger:
    """Records the static site hull of every sweep-step trace."""

    def __init__(self):
        self.sites: list = []
        self._count0 = 0
        self._prev = None

    @property
    def tags(self) -> list:
        return [full_site_tag(s) for s in self.sites]

    def new_traces(self) -> int:
        return simulator.TRACE_COUNT - self._count0

    def _record(self, site):
        self.sites.append(site)
        if self._prev is not None:
            self._prev(site)

    def __enter__(self):
        self._prev = simulator.TRACE_HOOK
        self._count0 = simulator.TRACE_COUNT
        simulator.TRACE_HOOK = self._record
        return self

    def __exit__(self, *exc):
        simulator.TRACE_HOOK = self._prev
        return False


@dataclass
class SanitizerSession:
    compiles: CompileWatcher
    traces: TraceLedger

    def assert_one_trace_per_bucket(self, plan):
        """The planner pipeline's per-bucket compile contract.

        Under ``pipeline=True`` every bucket of ``plan`` must have
        produced exactly one sweep-step trace — no bucket retraced
        (shape drift inside a bucket) and no trace for a hull the plan
        never declared. Failure names the offending hull tag so the
        guilty bucket is identifiable without bisecting.
        """
        counts = Counter(self.traces.tags)
        if hasattr(plan, "buckets"):          # planner.SweepPlan
            planned = [full_site_tag(b.hull) for b in plan.buckets]
        else:                                 # run_sweep_planned report
            planned = [b["hull"] for b in plan["buckets"]]
        for tag in planned:
            n = counts.get(tag, 0)
            if n > 1:
                raise AssertionError(
                    f"bucket hull {tag} was traced {n}x (expected "
                    "exactly 1): the pipeline retraced a bucket — "
                    "batch-shape or static-arg drift inside the "
                    "bucket")
            if n == 0:
                raise AssertionError(
                    f"bucket hull {tag} was never traced: the ledger "
                    "missed a bucket (stale _sweep_runner cache? "
                    "call simulator._sweep_runner.cache_clear() "
                    "before arming the ledger)")
        stray = set(counts) - set(planned)
        if stray:
            raise AssertionError(
                f"traces for undeclared hull(s) {sorted(stray)}: the "
                "pipeline compiled outside the plan's buckets")


@contextlib.contextmanager
def sweep_sanitizer():
    """transfer guard + compile watcher + trace ledger, as one session."""
    with transfer_sanitizer(), CompileWatcher() as cw, \
            TraceLedger() as tl:
        yield SanitizerSession(compiles=cw, traces=tl)
