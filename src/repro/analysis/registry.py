"""The checked-in contract registry (compile_sites.toml) and the two
checkers that diff it against the tree: RL002 (compile sites) and
RL004 (scenario-leaf sync).

compile_sites.toml is the single declarative home for:

* ``[analysis]``   — lint scope, hot/bit-exact module lists, exempt
  trees, and the suppression-count baseline;
* ``[[compile_site]]`` — every ``jit``/``pallas_call``/``lax.scan``
  callsite with its expected trace multiplicity (free prose, but it
  must be non-empty: a registered site with no stated multiplicity is
  itself RL002);
* ``[trace_count]`` — which functions carry the ``TRACE_COUNT += 1``
  probe, cross-checked against the code so the registry can never
  drift from the pin;
* ``[[blessed_transfer]]`` — the fetch points RL003 exempts (the same
  fetches HOST_TRANSFER_COUNT counts);
* ``[scenario_contract]`` + ``[[validation_exempt]]`` — the Scenario /
  SimParams field inventory RL004 enforces.

Adding a compile site, a host fetch, or a scenario knob without the
matching registry edit is a finding — the registry diff IS the review
artifact.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass
from pathlib import Path

from . import toml_lite
from .astutil import ModuleIndex, Project, dotted_name, resolves_to
from .findings import Finding

REGISTRY_RELPATH = "src/repro/analysis/compile_sites.toml"


@dataclass
class Config:
    raw: dict
    root: Path

    @property
    def analysis(self) -> dict:
        return self.raw.get("analysis", {})

    @property
    def lint_scope(self) -> list:
        return self.analysis.get("lint_scope", [])

    @property
    def hot_modules(self) -> list:
        return self.analysis.get("hot_modules", [])

    @property
    def bitexact_modules(self) -> list:
        return self.analysis.get("bitexact_modules", [])

    @property
    def lint_exempt(self) -> list:
        return self.analysis.get("lint_exempt", [])

    @property
    def max_suppressions(self) -> int:
        return int(self.analysis.get("max_suppressions", 0))

    def blessed(self, relpath: str) -> set:
        return {b["qualname"] for b in self.raw.get("blessed_transfer",
                                                    [])
                if b.get("file") == relpath}

    def is_exempt(self, relpath: str) -> bool:
        return any(relpath == e or relpath.startswith(e.rstrip("/") +
                                                      "/")
                   for e in self.lint_exempt)


def load_config(root: Path, path: Path | None = None) -> Config:
    p = path or (root / REGISTRY_RELPATH)
    return Config(raw=toml_lite.load(p), root=root)


# ---------------------------------------------------------------------------
# RL002 — compile-site registry
# ---------------------------------------------------------------------------

_KIND_NAMES = {
    "jit": ("jax.jit",),
    "pallas_call": ("jax.experimental.pallas.pallas_call",),
    "scan": ("jax.lax.scan",),
}


def _enclosing_qualname(mi: ModuleIndex, node) -> str:
    # innermost enclosing *def* — a lambda handed to scan is not a
    # registry address, its defining function is
    best = None
    for fi in mi.funcs.values():
        fn = fi.node
        if isinstance(fn, ast.Lambda):
            continue
        if fn.lineno <= node.lineno <= getattr(fn, "end_lineno",
                                               fn.lineno):
            if best is None or fn.lineno >= best.node.lineno:
                best = fi
    return best.qualname if best else "<module>"


def discover_compile_sites(mi: ModuleIndex):
    """Yield (qualname, kind, line) for each jit/pallas/scan site."""
    dec_nodes = set()
    for fi in mi.funcs.values():
        node = fi.node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for dec in node.decorator_list:
            dec_nodes.update(id(n) for n in ast.walk(dec))
            is_jit = resolves_to(mi, dec, "jax.jit")
            if isinstance(dec, ast.Call):
                if resolves_to(mi, dec.func, "jax.jit"):
                    is_jit = True
                elif (resolves_to(mi, dec.func, "functools.partial")
                      and dec.args
                      and resolves_to(mi, dec.args[0], "jax.jit")):
                    is_jit = True
            if is_jit:
                yield fi.qualname, "jit", dec.lineno
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call) or id(node) in dec_nodes:
            continue
        for kind, names in _KIND_NAMES.items():
            if resolves_to(mi, node.func, *names):
                yield _enclosing_qualname(mi, node), kind, node.lineno
                break


def _trace_probe_qualnames(mi: ModuleIndex) -> set:
    """Functions containing a ``TRACE_COUNT += 1`` probe."""
    out = set()
    for node in ast.walk(mi.tree):
        if (isinstance(node, ast.AugAssign)
                and isinstance(node.target, ast.Name)
                and node.target.id == "TRACE_COUNT"):
            out.add(_enclosing_qualname(mi, node))
    return out


def check_registry(proj: Project, cfg: Config) -> list:
    out = []
    entries = cfg.raw.get("compile_site", [])
    declared = {}
    for i, e in enumerate(entries):
        key = (e.get("file", ""), e.get("qualname", ""),
               e.get("kind", ""))
        if not all(key):
            out.append(Finding(
                "RL002", REGISTRY_RELPATH, 1,
                f"compile_site entry #{i + 1} is missing "
                "file/qualname/kind"))
            continue
        if key in declared:
            out.append(Finding(
                "RL002", REGISTRY_RELPATH, 1,
                f"duplicate compile_site entry {key}"))
        declared[key] = e
        if not str(e.get("multiplicity", "")).strip():
            out.append(Finding(
                "RL002", REGISTRY_RELPATH, 1,
                f"compile_site {key} declares no trace multiplicity"))

    matched = set()
    for mi in proj.modules.values():
        for qualname, kind, line in discover_compile_sites(mi):
            key = (mi.path, qualname, kind)
            if key in declared:
                matched.add(key)
            else:
                out.append(Finding(
                    "RL002", mi.path, line,
                    f"unregistered {kind} compile site in {qualname} "
                    "(declare it in analysis/compile_sites.toml with "
                    "its expected trace multiplicity)"))
    for key in declared:
        if key not in matched and proj.by_path(key[0]) is not None:
            out.append(Finding(
                "RL002", REGISTRY_RELPATH, 1,
                f"registry drift: declared compile site {key} no "
                "longer exists in the code"))

    tc = cfg.raw.get("trace_count", {})
    tc_file = tc.get("file")
    if tc_file:
        mi = proj.by_path(tc_file)
        if mi is not None:
            actual = _trace_probe_qualnames(mi)
            want = set(tc.get("counted_fns", []))
            for q in actual - want:
                out.append(Finding(
                    "RL002", tc_file, 1,
                    f"TRACE_COUNT probe in {q} is not declared in "
                    "[trace_count] counted_fns (registry drift vs the "
                    "trace pin)"))
            for q in want - actual:
                out.append(Finding(
                    "RL002", REGISTRY_RELPATH, 1,
                    f"[trace_count] declares {q} but no TRACE_COUNT "
                    "probe exists there"))
    return out


# ---------------------------------------------------------------------------
# RL004 — scenario-leaf sync
# ---------------------------------------------------------------------------

def _class_fields(cls: ast.ClassDef) -> dict:
    """AnnAssign field name -> line for a NamedTuple/dataclass body."""
    return {s.target.id: s.lineno for s in cls.body
            if isinstance(s, ast.AnnAssign)
            and isinstance(s.target, ast.Name)}


def _module_assign(mi: ModuleIndex, name: str):
    for node in mi.tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    return node
    return None


def check_scenario_contract(proj: Project, cfg: Config) -> list:
    out = []
    sc = cfg.raw.get("scenario_contract")
    if not sc:
        # mini-configs (the analyzer's own test fixtures) may opt out;
        # the real registry must carry the table
        if cfg.analysis.get("require_scenario_contract", True):
            return [Finding("RL004", REGISTRY_RELPATH, 1,
                            "missing [scenario_contract] table")]
        return []
    mi = proj.by_path(sc.get("file", ""))
    if mi is None:
        return [Finding("RL004", REGISTRY_RELPATH, 1,
                        f"scenario_contract.file {sc.get('file')!r} is "
                        "not in the lint scope")]

    classes = {n.name: n for n in mi.tree.body
               if isinstance(n, ast.ClassDef)}
    scen_cls = classes.get(sc.get("scenario_class", "Scenario"))
    par_cls = classes.get(sc.get("params_class", "SimParams"))

    # 1. Scenario leaves <-> contract inventory
    if scen_cls is None:
        out.append(Finding("RL004", mi.path, 1,
                           "scenario class not found"))
    else:
        actual = _class_fields(scen_cls)
        want = set(sc.get("scenario_fields", []))
        for f in sorted(set(actual) - want):
            out.append(Finding(
                "RL004", mi.path, actual[f],
                f"Scenario leaf {f!r} is not in the contract's "
                "scenario_fields (new knob: register it AND bump "
                "schema_version)"))
        for f in sorted(want - set(actual)):
            out.append(Finding(
                "RL004", REGISTRY_RELPATH, 1,
                f"contract lists scenario field {f!r} that Scenario no "
                "longer has"))
        # every leaf must be consumed somewhere outside the class body
        reads = {n.attr for n in ast.walk(mi.tree)
                 if isinstance(n, ast.Attribute)
                 and not (scen_cls.lineno <= n.lineno
                          <= scen_cls.end_lineno)}
        for f, line in actual.items():
            if f in want and f not in reads:
                out.append(Finding(
                    "RL004", mi.path, line,
                    f"Scenario leaf {f!r} is never read in the "
                    "simulator (dead knob)"))

    # 2. schema version pin
    ver_node = _module_assign(mi, sc.get("schema_version_name",
                                         "SIM_SCHEMA_VERSION"))
    if ver_node is None:
        out.append(Finding("RL004", mi.path, 1,
                           "SIM_SCHEMA_VERSION assignment not found"))
    elif isinstance(ver_node.value, ast.Constant):
        if ver_node.value.value != sc.get("schema_version"):
            out.append(Finding(
                "RL004", mi.path, ver_node.lineno,
                f"SIM_SCHEMA_VERSION is {ver_node.value.value} but the "
                f"contract pins {sc.get('schema_version')} (bump both "
                "together)"))

    # 3. fingerprint knobs == the module's KNOBS literals. The fault
    # pin is mandatory; further fingerprints (the flow engine's
    # FLOW_KNOBS) are checked when the contract declares them — same
    # cache-fingerprint-moves-with-the-registry rule for every family.
    fp_pins = [(sc.get("fingerprint_name", "FAULT_KNOBS"),
                "fingerprint_params", True)]
    if "flow_fingerprint_params" in sc:
        fp_pins.append((sc.get("flow_fingerprint_name", "FLOW_KNOBS"),
                        "flow_fingerprint_params", True))
    for fp_name, fp_key, _required in fp_pins:
        fk_node = _module_assign(mi, fp_name)
        if fk_node is None:
            out.append(Finding("RL004", mi.path, 1,
                               f"{fp_name} assignment not found"))
            continue
        lits = [e.value for e in ast.walk(fk_node.value)
                if isinstance(e, ast.Constant)
                and isinstance(e.value, str)]
        if lits != list(sc.get(fp_key, [])):
            out.append(Finding(
                "RL004", mi.path, fk_node.lineno,
                f"{fp_name} {tuple(lits)} != contract "
                f"{fp_key} "
                f"{tuple(sc.get(fp_key, []))} — the "
                "cache fingerprint and the registry must move "
                "together"))

    # 4. SimParams validation table
    if par_cls is None:
        out.append(Finding("RL004", mi.path, 1,
                           "params class not found"))
        return out
    actual_p = _class_fields(par_cls)
    validated = set(sc.get("validated_params", []))
    exempt = {e["field"]: e for e in cfg.raw.get("validation_exempt",
                                                 [])}
    for f, e in exempt.items():
        if not str(e.get("reason", "")).strip():
            out.append(Finding(
                "RL004", REGISTRY_RELPATH, 1,
                f"validation_exempt entry {f!r} carries no reason"))
    post = None
    for n in par_cls.body:
        if isinstance(n, ast.FunctionDef) and n.name == "__post_init__":
            post = n
    post_reads = set()
    if post is not None:
        post_reads = {a.attr for a in ast.walk(post)
                      if isinstance(a, ast.Attribute)
                      and isinstance(a.value, ast.Name)
                      and a.value.id == "self"}
    for f, line in actual_p.items():
        if f in validated:
            if f not in post_reads:
                out.append(Finding(
                    "RL004", mi.path, line,
                    f"SimParams.{f} is declared validated but "
                    "__post_init__ never checks it"))
        elif f not in exempt:
            out.append(Finding(
                "RL004", mi.path, line,
                f"SimParams.{f} is in neither validated_params nor "
                "[[validation_exempt]] — every knob needs a range "
                "check or a stated exemption"))
    for f in sorted((validated | set(exempt)) - set(actual_p)):
        out.append(Finding(
            "RL004", REGISTRY_RELPATH, 1,
            f"contract mentions SimParams field {f!r} that no longer "
            "exists"))
    # every fingerprint knob (any family) must be a real SimParams field
    for fp_key in ("fingerprint_params", "flow_fingerprint_params"):
        for f in sc.get(fp_key, []):
            if f not in actual_p:
                out.append(Finding(
                    "RL004", REGISTRY_RELPATH, 1,
                    f"{fp_key} lists {f!r} which is not a "
                    "SimParams field"))
    return out
