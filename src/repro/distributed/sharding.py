"""Declarative sharding rules: params / optimizer state / caches / batches.

Rules are path-based over the params pytree produced by ``model.init_params``
(obtained via ``jax.eval_shape`` so no memory is touched). Policy:

  * TP   : d_ff, attention heads, vocab (head), rwkv/mamba inner dims over
           the 'model' axis.
  * FSDP : the complementary dim of every large matrix over 'data'
           (all-gathered at use; XLA inserts the collectives).
  * EP   : expert dims handled by moe.moe_param_specs (shard_map).
  * DP   : batch over ('pod','data') (the pod axis extends data).
  * SP   : decode caches shard KV-seq over data when batch is unshardable
           (long_500k with global_batch=1).

MLA attention matrices are kept model-replicated (minicpm3's 40 heads do
not divide a 16-way axis; the model is 4B params — see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import model as model_lib
from repro.models.moe import DistContext, moe_param_specs


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return out


def _leaf_spec(cfg, dist: DistContext, names: list[str], ndim: int) -> P:
    ma = dist.model_axis
    fsdp = "data" if (cfg.fsdp and cfg.zero >= 3) else None
    name = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    moe_specs = moe_param_specs(cfg, dist) if cfg.n_experts else {}

    if name == "embed":
        if cfg.tie_embeddings:
            # the table doubles as the LM head: vocab over model so logits
            # come out vocab-sharded with no resharding of the (tokens,
            # vocab) tensor; FSDP on d_model.
            return P(ma, fsdp)
        return P(fsdp, ma)
    if name == "head":
        return P(ma, fsdp)
    if parent == "moe":
        return moe_specs[name]
    if parent == "attn":
        if cfg.attn_type == "mla":
            return P(fsdp) if ndim >= 2 else P()
        if name in ("wq",):
            return P(fsdp, ma)
        if name in ("wk", "wv"):
            # kv heads replicated over model (n_kv < model-axis in general)
            return P(fsdp, None)
        if name == "wo":
            return P(ma, fsdp)
        return P()                      # q_norm / k_norm
    if parent == "mlp":
        if name in ("w_gate", "w_up"):
            return P(fsdp, ma)
        return P(ma, fsdp)              # w_down
    if parent == "rwkv":
        if name in ("wr", "wk", "wv", "wg", "cm_wk"):
            return P(fsdp, ma)
        if name in ("wo", "cm_wv"):
            return P(ma, fsdp)
        if name == "cm_wr":
            return P(fsdp, None)
        return P()                      # loras, maa, u, gn_w, w0
    if parent == "mamba":
        if name == "in_proj":
            return P(fsdp, ma)
        if name == "out_proj":
            return P(ma, fsdp)
        if name == "conv_w":
            return P(None, ma)
        if name in ("conv_b", "D", "dt_bias"):
            return P(ma)
        if name == "x_proj":
            return P(ma, None)
        if name == "dt_proj":
            return P(None, ma)
        if name == "A_log":
            return P(ma, None)
        return P()
    return P()                          # norms and other vectors


def opt_extra_shard(cfg, dist: DistContext, spec, shp):
    """ZeRO-2: shard optimizer moments over 'data' on the first dim that
    is unsharded and divisible (params stay replicated over data)."""
    if cfg.zero != 2:
        return spec
    parts = list(spec) + [None] * (len(shp.shape) - len(spec))
    for i, (ax, n) in enumerate(zip(parts, shp.shape)):
        if ax is None and n % dist.data_size == 0 and n > 1:
            parts[i] = "data" if dist.data_size ==                 dist.mesh.shape["data"] else dist.data_axes
            return P(*parts)
    return spec


def param_specs(cfg, dist: DistContext):
    """PartitionSpec pytree matching init_params(cfg)."""
    shapes = jax.eval_shape(
        lambda k: model_lib.init_params(cfg, k),
        jax.ShapeDtypeStruct((2,), jnp.uint32))

    def rule(path, leaf):
        names = _path_names(path)
        spec = _leaf_spec(cfg, dist, names, leaf.ndim)
        if names[0] == "stack":          # stacked layer dim is unsharded
            spec = P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(rule, shapes), shapes


def batch_specs(cfg, shape, dist: DistContext):
    """PartitionSpecs for the input batch of one shape cell."""
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"
    from repro.configs.base import input_specs
    specs = input_specs(cfg, shape)
    B = shape.global_batch
    b_ax = da if B % dist.data_size == 0 else None

    out = {}
    for k, v in specs.items():
        out[k] = P(b_ax, *([None] * (v.ndim - 1)))
    return out, specs


def cache_specs(cfg, shape, dist: DistContext):
    """PartitionSpecs for the decode cache of one shape cell.

    batch over data when divisible; otherwise KV-seq over data (SP).
    rwkv/mamba states shard their head/inner dim over 'model'.
    """
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"
    ma = dist.model_axis
    B = shape.global_batch
    batch_ok = B % dist.data_size == 0
    b_ax = da if batch_ok else None

    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, B, shape.seq_len))

    def _seq_axes(S, kv_sharded):
        """Shard the KV-seq dim over every axis not already used: the data
        axes when batch doesn't shard, the model axis when kv-heads don't
        (flash-decoding-style partial softmax; GSPMD inserts the psum)."""
        axes = []
        if not batch_ok:
            axes.extend(da if isinstance(da, tuple) else (da,))
        if not kv_sharded:
            axes.append(ma)
        n = 1
        for a in axes:
            n *= dist.mesh.shape[a]
        if axes and S % n == 0:
            return tuple(axes)
        return None

    def rule(path, leaf):
        names = _path_names(path)
        name = names[-1]
        stacked = names[0] == "stack"
        lead = (None,) if stacked else ()
        if name == "pos_offset":
            return P(b_ax)
        if name in ("k", "v"):           # (B, S, Hkv, dh)
            S, Hkv = leaf.shape[-3], leaf.shape[-2]
            kv_ok = Hkv % dist.model_size == 0
            kv_ax = ma if kv_ok else None
            return P(*lead, b_ax, _seq_axes(S, kv_ok), kv_ax, None)
        if name in ("c_kv", "k_rope"):   # (B, S, r) - no head dim to shard
            S = leaf.shape[-2]
            return P(*lead, b_ax, _seq_axes(S, False), None)
        if name == "wkv":                # (B, H, dh, dh)
            H = leaf.shape[-3]
            h_ax = ma if H % dist.model_size == 0 else None
            return P(*lead, b_ax, h_ax, None, None)
        if name in ("att_shift", "cm_shift"):   # (B, d)
            return P(*lead, b_ax, None)
        if name == "conv":               # (B, d_conv-1, d_in)
            return P(*lead, b_ax, None, ma)
        if name == "h":                  # (B, d_in, N)
            return P(*lead, b_ax, ma, None)
        return P(*lead, *([None] * (leaf.ndim - len(lead))))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes), cache_shapes


def logits_spec(cfg, dist: DistContext, global_batch: int | None = None):
    da = dist.data_axes if len(dist.data_axes) > 1 else "data"
    b_ax = da if (global_batch is None
                  or global_batch % dist.data_size == 0) else None
    v_ax = dist.model_axis if cfg.padded_vocab % dist.model_size == 0 \
        else None
    return P(b_ax, v_ax)


def to_shardings(tree_specs, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
