"""Optional pipeline parallelism: microbatched GPipe-style stage executor
built on shard_map + collective_permute.

The stage axis maps onto 'pod' (or any mesh axis): stage s holds layers
[s*L/S, (s+1)*L/S). Microbatches stream through; activations hop stages
with lax.ppermute. Bubble fraction = (S-1)/(M+S-1). This executor is
unit-tested at small scale (tests/test_pipeline_parallel.py) and offered
as a config choice; the default cell configs use FSDP+TP+EP which wins
at the assigned shapes (see DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map


def pipeline_apply(mesh, stage_axis: str, n_stages: int, layer_fn,
                   stacked_params, x, n_micro: int):
    """Run x (B, ...) through n_stages pipeline stages of layer_fn.

    stacked_params: pytree with leading dim == n_stages (one slice per
    stage). x is consumed microbatch-by-microbatch (B % n_micro == 0).
    Returns the final-stage output in original batch order.
    """
    B = x.shape[0]
    assert B % n_micro == 0
    mb = B // n_micro

    def stage_body(params_local, x_local):
        # params_local arrives with a size-1 leading shard dim: drop it
        params_local = jax.tree.map(lambda a: a[0], params_local)
        # x_local: (n_micro, mb, ...) all microbatches, this stage's copy
        s = jax.lax.axis_index(stage_axis)
        perm_fwd = [(i, (i + 1) % n_stages) for i in range(n_stages)]
        n_ticks = n_micro + n_stages - 1

        def tick(carry, t):
            buf, outs = carry           # buf: (mb, ...) in-flight act
            # stage 0 injects microbatch t (if in range)
            inject = jnp.where(t < n_micro, t, n_micro - 1)
            x_in = jax.lax.dynamic_index_in_dim(x_local, inject, 0,
                                                keepdims=False)
            cur = jnp.where(s == 0, x_in, buf)
            y = layer_fn(params_local, cur)
            # last stage banks its result at position t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = (s == n_stages - 1) & (t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs, jnp.where(take, y, jax.lax.dynamic_index_in_dim(
                    outs, out_idx, 0, keepdims=False)), out_idx, 0)
            # hop activations forward one stage
            buf = jax.lax.ppermute(y, stage_axis, perm_fwd)
            return (buf, outs), None

        buf0 = jnp.zeros_like(x_local[0])
        outs0 = jnp.zeros_like(x_local)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(n_ticks))
        # only the last stage banked non-zero results; psum broadcasts them
        return jax.lax.psum(outs, stage_axis)

    xm = x.reshape(n_micro, mb, *x.shape[1:])
    out = shard_map(
        stage_body, mesh=mesh,
        in_specs=(P(stage_axis), P()),      # params sharded by stage
        out_specs=P(),                      # every stage returns; last wins
        check_vma=False,
    )(stacked_params, xm)
    return out.reshape(B, *x.shape[1:])
