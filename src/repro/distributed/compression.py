"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback (residual carried across steps so compression error does
not accumulate -- EF-SGD style). Opt-in wrapper around the grad tree.

At 1000+ nodes the gradient all-reduce of a dense model is the largest
inter-pod collective; 4x compression cuts the 'pod' axis traffic
proportionally (the ICI-gating study reads this directly from the HLO
of the compressed variant).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_state_init(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state):
    """error-feedback compress: g' = Q(g + e); e' = (g + e) - g'."""
    def one(g, e):
        t = g.astype(jnp.float32) + e
        q, s = quantize_int8(t)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), t - deq
    flat, treedef = jax.tree_util.tree_flatten(grads)
    ef = treedef.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat, ef)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))
