"""Kimi K2 — trillion-param MoE (assigned spec: GQA kv=8, 384e top-8).

61L d_model=7168 64H (GQA kv=8) expert d_ff=2048 vocab=163840, MoE 384
experts top-8, first layer dense (dense d_ff uses the standard 4x-ish
intermediate so the dense layer is not degenerate). Adafactor optimizer:
Adam moments for ~1T params cannot fit 256 x 16 GB HBM (see DESIGN.md).
[arXiv:2501.kimi2; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv=8,
    d_head=128,
    d_ff=18432,            # dense FFN width for the first (dense) layer
    vocab=163840,
    n_experts=384,
    top_k=8,
    d_expert=2048,
    first_dense=1,
    rope_theta=1_000_000.0,
    optimizer="adafactor",
)
