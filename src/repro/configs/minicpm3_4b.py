"""MiniCPM3-4B — dense with Multi-head Latent Attention (MLA).

62L d_model=2560 40H d_ff=6400 vocab=73448. MLA: q_lora 768, kv_lora 256,
rope 32 + nope 64 per head, v_head 64. Decode uses the absorbed-weight
latent-cache formulation (cache = c_kv + k_rope only).
[hf:openbmb/MiniCPM3-4B; hf]"""
from repro.configs.base import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv=40,
    d_head=64,
    d_ff=6400,
    vocab=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
    rope_theta=1_000_000.0,
)
