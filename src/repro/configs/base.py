"""Config system: model configs, input-shape cells, and ShapeDtypeStruct specs.

Every assigned architecture is a ``ModelConfig`` in ``repro/configs/<id>.py``.
Shape cells (train_4k / prefill_32k / decode_32k / long_500k) are defined here
once; ``cells_for(cfg)`` applies the skip rules from DESIGN.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encoder | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int

    # attention flavor
    attn_type: str = "gqa"      # gqa | mla | none (attention-free)
    qk_norm: bool = False
    swa_window: int = 0         # 0 = full attention
    causal: bool = True         # False for encoder-only
    use_rope: bool = True       # Jamba uses no positional encoding
    rope_theta: float = 1_000_000.0
    mla: MLAConfig | None = None

    mlp_variant: str = "swiglu"   # swiglu (3 mats) | gelu (2 mats)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0           # expert hidden dim (may differ from d_ff)
    first_dense: int = 0        # first N layers use a dense FFN (Kimi K2)
    moe_period: int = 1         # MoE FFN every `moe_period` layers
    moe_offset: int = 0
    capacity_factor: float = 1.25

    # hybrid (Jamba): layer i is attention iff i % attn_period == attn_offset
    attn_period: int = 1
    attn_offset: int = 0
    mamba: MambaConfig | None = None

    # rwkv
    rwkv_head_dim: int = 64

    # modality frontend (stubbed: input_specs feeds embeddings directly)
    frontend: str = "none"      # none | audio_frames | vision_patches
    n_frontend_tokens: int = 0  # e.g. 256 vision patch tokens

    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16

    # training-policy knobs (overridable per cell by the launcher)
    remat: bool = True
    optimizer: str = "adamw"    # adamw | adafactor
    unroll: bool = False        # python-loop the stack (FLOP-accounting mode)
    attn_chunk: int = 1024      # KV/Q chunk for online-softmax attention
    act_shard: str = "dmodel"   # residual-stream sharding: none | seq | dmodel
    # sharding policy
    fsdp: bool = True           # shard params over the data axis too
    zero: int = 3               # 3 = FSDP params+opt; 2 = params
                                # replicated over data, opt state sharded
    moe_combine: str = "psum"   # psum | psum_scatter (EP combine)
    microbatches: int = 1       # gradient-accumulation chunks per step
    decode_sp: bool = False     # shard_map flash-decode for seq-sharded KV
    expert_parallel: bool = True  # shard experts over model axis when divisible

    @property
    def attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 512 so embedding/head shard evenly
        over the model axis (Megatron-style vocab padding)."""
        return -(-self.vocab // 512) * 512

    @property
    def is_encoder(self) -> bool:
        return self.family == "encoder"

    def layer_kind(self, i: int) -> str:
        """'attn' or 'mamba' mixer for layer i."""
        if self.attention_free:
            return "rwkv" if self.family == "ssm" else "mamba"
        if self.mamba is not None:  # hybrid
            return "attn" if i % self.attn_period == self.attn_offset else "mamba"
        return "attn"

    def ffn_kind(self, i: int) -> str:
        """'mlp' or 'moe' FFN for layer i."""
        if self.n_experts and i >= self.first_dense and \
                i % self.moe_period == self.moe_offset:
            return "moe"
        return "mlp"

    def n_params(self) -> int:
        """Analytic parameter count (used for 6ND MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d          # embedding
        if not self.tie_embeddings and not self.is_encoder:
            total += self.vocab * d     # lm head
        if self.is_encoder:
            total += self.vocab * d     # classifier head over small vocab
        for i in range(L):
            kind = self.layer_kind(i)
            if kind == "attn":
                if self.attn_type == "mla":
                    m = self.mla
                    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
                    total += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk_head
                    total += d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    total += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    total += self.n_heads * m.v_head_dim * d
                else:
                    total += d * self.n_heads * self.d_head      # q
                    total += 2 * d * self.n_kv * self.d_head     # k, v
                    total += self.n_heads * self.d_head * d      # o
            elif kind == "mamba":
                mc = self.mamba
                d_in = mc.expand * d
                dt_rank = mc.dt_rank or -(-d // 16)
                total += d * 2 * d_in                 # in_proj
                total += d_in * mc.d_conv             # conv
                total += d_in * (dt_rank + 2 * mc.d_state)   # x_proj
                total += dt_rank * d_in + d_in        # dt_proj
                total += d_in * mc.d_state + d_in     # A, D
                total += d_in * d                     # out_proj
            elif kind == "rwkv":
                h = d // self.rwkv_head_dim
                total += 4 * d * d + d * d            # r,k,v,g,o  (time mix)
                total += 5 * 32 * d * 2               # ddlerp loras (approx)
                total += 64 * d * 2                   # decay lora
                total += 2 * h * self.rwkv_head_dim   # u, ln params per head
            if kind != "rwkv":
                if self.ffn_kind(i) == "moe":
                    total += d * self.n_experts       # router
                    total += self.n_experts * 3 * d * self.d_expert
                else:
                    n_mats = 3 if self.mlp_variant == "swiglu" else 2
                    total += n_mats * d * self.d_ff
            else:
                total += d * int(3.5 * d) * 2         # rwkv channel mix (k, v)
        return total

    def n_active_params(self) -> int:
        """Active (per-token) parameter count for MoE archs."""
        if not self.n_experts:
            return self.n_params()
        # Replace full expert count with top_k in the FFN term.
        full = self.n_params()
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.ffn_kind(i) == "moe")
        moe_all = n_moe_layers * self.n_experts * 3 * self.d_model * self.d_expert
        moe_act = n_moe_layers * self.top_k * 3 * self.d_model * self.d_expert
        return full - moe_all + moe_act


# ---------------------------------------------------------------------------
# Shape cells
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: ShapeConfig
    run: bool
    skip_reason: str = ""


def _subquadratic(cfg: ModelConfig) -> bool:
    """long_500k eligibility: SSM / hybrid / linear-attn / sliding-window."""
    return (cfg.family in ("ssm", "hybrid")) or (cfg.swa_window > 0)


def cells_for(cfg: ModelConfig) -> list[Cell]:
    out = []
    for shape in SHAPES.values():
        if shape.kind == "decode" and cfg.is_encoder:
            out.append(Cell(cfg.name, shape, False,
                            "encoder-only arch has no decode step"))
            continue
        if shape.name == "long_500k" and not _subquadratic(cfg):
            out.append(Cell(cfg.name, shape, False,
                            "pure full-attention arch; 500k decode needs "
                            "sub-quadratic attention (see DESIGN.md)"))
            continue
        out.append(Cell(cfg.name, shape, True))
    return out


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, never allocated)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """Model inputs for one cell as ShapeDtypeStructs (dry-run friendly)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.frontend == "audio_frames":
        # HuBERT-style: precomputed frame embeddings + mask + frame targets.
        specs = {
            "features": sds((B, S, cfg.d_model), cfg.dtype),
            "mask": sds((B, S), jnp.bool_),
            "targets": sds((B, S), jnp.int32),
        }
        return specs
    if cfg.frontend == "vision_patches":
        P = cfg.n_frontend_tokens
        if shape.kind == "decode":
            return {
                "token": sds((B, 1), jnp.int32),
                "pos": sds((B,), jnp.int32),
            }
        return {
            "patches": sds((B, P, cfg.d_model), cfg.dtype),
            "tokens": sds((B, S - P), jnp.int32),
            "targets": sds((B, S - P), jnp.int32),
        }
    if shape.kind == "decode":
        return {
            "token": sds((B, 1), jnp.int32),
            "pos": sds((B,), jnp.int32),
        }
    specs = {"tokens": sds((B, S), jnp.int32)}
    if shape.kind == "train":
        specs["targets"] = sds((B, S), jnp.int32)
    return specs


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small: dict[str, Any] = dict(
        n_layers=max(2, cfg.attn_period) if cfg.mamba is not None else 2,
        d_model=64,
        n_heads=4,
        n_kv=min(cfg.n_kv, 2) if cfg.n_kv > 1 else 1,
        d_head=16,
        d_ff=128,
        vocab=256,
        dtype=jnp.float32,
        remat=False,
        fsdp=False,
    )
    if cfg.attn_type == "mla":
        small["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                 qk_nope_head_dim=8, qk_rope_head_dim=8,
                                 v_head_dim=8)
    if cfg.n_experts:
        small["n_experts"] = 4
        small["top_k"] = 2
        small["d_expert"] = 64
    if cfg.mamba is not None:
        small["mamba"] = MambaConfig(d_state=4, d_conv=4, expand=2)
        small["n_layers"] = cfg.attn_period  # one full hybrid period
    if cfg.family == "ssm":
        small["rwkv_head_dim"] = 16
    if cfg.frontend == "vision_patches":
        small["n_frontend_tokens"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
