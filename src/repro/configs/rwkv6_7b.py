"""RWKV-6 "Finch" 7B — attention-free RNN with data-dependent decay.

32L d_model=4096, head_dim 64 (64 heads), channel-mix ratio 3.5,
vocab=65536. O(1) decode state -> runs long_500k. [arXiv:2404.05892; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # d_model / rwkv_head_dim
    n_kv=64,
    d_head=64,
    d_ff=14336,          # channel-mix hidden (~3.5x)
    vocab=65536,
    attn_type="none",
    rwkv_head_dim=64,
)
