"""HuBERT X-Large — encoder-only audio transformer.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 (masked-frame cluster
targets). The CNN waveform frontend is a STUB: input_specs() feeds
precomputed frame embeddings (B, T, d_model). Bidirectional attention,
masked-prediction CE loss; no decode shapes. [arXiv:2106.07447; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="encoder",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv=16,
    d_head=80,
    d_ff=5120,
    vocab=504,
    mlp_variant="gelu",   # classic transformer-encoder 2-matrix FFN
    causal=False,
    frontend="audio_frames",
    rope_theta=10_000.0,
)
