"""InternVL2-Llama3-76B — VLM; this config is the LLM BACKBONE only.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256. The InternViT
vision tower is a STUB: input_specs() provides precomputed patch
embeddings (B, 256, d_model) fused at the front of the sequence.
[arXiv:2404.16821; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv=8,
    d_head=128,
    d_ff=28672,
    vocab=128256,
    frontend="vision_patches",
    n_frontend_tokens=256,
    rope_theta=500_000.0,
)
