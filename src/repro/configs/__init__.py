"""Architecture registry: ``get_config("<arch-id>")``."""
from __future__ import annotations

from repro.configs.base import (Cell, MambaConfig, MLAConfig, ModelConfig,
                                ShapeConfig, SHAPES, cells_for, input_specs,
                                reduced)

from repro.configs.kimi_k2_1t_a32b import CONFIG as _kimi
from repro.configs.mixtral_8x7b import CONFIG as _mixtral
from repro.configs.qwen3_0_6b import CONFIG as _qwen3_06
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.granite_34b import CONFIG as _granite
from repro.configs.qwen3_8b import CONFIG as _qwen3_8b
from repro.configs.hubert_xlarge import CONFIG as _hubert
from repro.configs.rwkv6_7b import CONFIG as _rwkv6
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.internvl2_76b import CONFIG as _internvl

REGISTRY: dict[str, ModelConfig] = {
    c.name: c for c in [
        _kimi, _mixtral, _qwen3_06, _minicpm3, _granite,
        _qwen3_8b, _hubert, _rwkv6, _jamba, _internvl,
    ]
}

ARCH_IDS = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    return REGISTRY[name]


__all__ = [
    "ARCH_IDS", "Cell", "MambaConfig", "MLAConfig", "ModelConfig", "REGISTRY",
    "SHAPES", "ShapeConfig", "cells_for", "get_config", "input_specs",
    "reduced",
]
