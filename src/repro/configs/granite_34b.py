"""Granite-34B-Code — llama-arch dense with MQA (kv=1).

88L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152. [arXiv:2405.04324; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv=1,
    d_head=128,
    d_ff=24576,
    vocab=49152,
    mlp_variant="gelu",   # GPTBigCode-style 2-matrix MLP
    rope_theta=10_000.0,
)
