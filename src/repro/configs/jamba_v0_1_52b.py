"""Jamba v0.1 52B — hybrid Mamba + attention (1:7) with MoE (16e top-2).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536. Attention at
layer i where i % 8 == 4 (attn_layer_period=8, offset=4); MoE FFN every
other layer (period 2, offset 1). Mamba: d_state 16, conv 4, expand 2.
[arXiv:2403.19887; hf]"""
from repro.configs.base import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_head=128,
    d_ff=14336,
    vocab=65536,
    n_experts=16,
    top_k=2,
    d_expert=14336,
    moe_period=2,
    moe_offset=1,
    attn_period=8,
    attn_offset=4,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    use_rope=False,       # Jamba has no positional encoding (Mamba provides it)
    rope_theta=10_000.0,
)
