"""Serving launcher: batched prefill + lock-step decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --batch 8 --prompt-len 32 --gen 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced as reduce_cfg
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    B, P = args.batch, args.prompt_len
    max_len = P + args.gen
    prompts = jax.random.randint(key, (B, P), 0, cfg.vocab)

    logits, cache = jax.jit(lambda p, b: M.prefill(cfg, p, b))(
        params, {"tokens": prompts})
    full = M.init_cache(cfg, B, max_len, dtype=cfg.dtype)

    def merge(dst, src):
        if dst.shape == src.shape:
            return src
        for ax in range(dst.ndim):
            if dst.shape[ax] != src.shape[ax]:
                sl = [slice(None)] * dst.ndim
                sl[ax] = slice(0, src.shape[ax])
                return dst.at[tuple(sl)].set(src)
        return src

    cache = jax.tree.map(merge, full, cache)
    dec = jax.jit(lambda p, c, t, po: M.decode_step(cfg, p, c, t, po))
    tok = jnp.argmax(logits, -1)[:, None]
    t0 = time.perf_counter()
    n = 0
    for t in range(P, max_len - 1):
        logits, cache = dec(params, cache, tok,
                            jnp.full((B,), t, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None]
        n += B
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {n} tokens in {dt:.2f}s -> {n/dt:.1f} tok/s")


if __name__ == "__main__":
    main()
