"""Compatibility shim: the HLO parsers moved to ``repro.analysis.hlo``
so the compiled-artifact auditor and the launch dry-run accounting
share one vocabulary. Import from there in new code."""
from __future__ import annotations

from repro.analysis.hlo import (          # noqa: F401
    CollectiveStats,
    cost_stats,
    memory_stats,
    parse_collectives,
)
