"""Production mesh factory.

Single pod: 16 x 16 = 256 chips ('data', 'model').
Multi-pod:  2 x 16 x 16 = 512 chips ('pod', 'data', 'model'); the 'pod'
axis extends data parallelism (gradient all-reduce crosses the inter-pod
links; the ICI-gating study in core/ici_gating.py consumes exactly that
traffic split).

Defined as functions so importing this module never touches jax device
state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 spells explicit/auto sharding via AxisType
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:  # older jax: Auto is the only (implicit) behaviour
    def _axis_kw(n: int) -> dict:
        return {}

from repro.models.moe import DistContext

try:  # jax >= 0.6
    set_mesh = jax.set_mesh
except AttributeError:
    # older jax: Mesh is itself the context manager that scopes
    # PartitionSpec resolution for jit/shard_map
    def set_mesh(mesh):
        return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             **_axis_kw(3))
    return jax.make_mesh((data, model), ("data", "model"), **_axis_kw(2))


def dist_for(mesh) -> DistContext:
    axes = mesh.axis_names
    data_axes = ("pod", "data") if "pod" in axes else ("data",)
    return DistContext(mesh=mesh, data_axes=data_axes, model_axis="model")
