"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b \
      --steps 1000 --ckpt-dir /ckpts/qwen3-8b [--reduced]

On a real TPU fleet this process runs per host (jax.distributed
initializes from the cluster env); in this CPU container use --reduced
for a smoke-scale run. XLA flags enable the latency-hiding scheduler so
collectives overlap compute on TPU.
"""
import os
os.environ.setdefault(
    "LIBTPU_INIT_ARGS",
    "--xla_tpu_enable_latency_hiding_scheduler=true")

import argparse

import jax

from repro.configs import get_config, reduced as reduce_cfg
from repro.data.pipeline import DataConfig
from repro.launch.mesh import dist_for, make_production_mesh
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=1000)
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="tiny same-family config (CPU smoke)")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
        dist = None
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        dist = dist_for(mesh)

    data = DataConfig(vocab=cfg.vocab,
                      seq_len=args.seq_len or (64 if args.reduced else 4096),
                      global_batch=args.global_batch
                      or (8 if args.reduced else 256))
    tcfg = TrainerConfig(ckpt_dir=args.ckpt_dir, total_steps=args.steps,
                         ckpt_every=args.ckpt_every, peak_lr=args.lr)
    trainer = Trainer(cfg=cfg, tcfg=tcfg, data=data, dist=dist)
    state, start = trainer.restore_or_init()
    print(f"training {cfg.name} from step {start} on "
          f"{jax.device_count()} device(s)")
    trainer.run(state, start)
    print("done; losses:",
          [round(m["loss"], 4) for m in trainer.metrics_log[-5:]])


if __name__ == "__main__":
    main()
