import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each runnable cell this produces, per mesh:
  * the scanned-stack compile -> memory_analysis (peak bytes/device proof)
  * cost_analysis flops/bytes (per-layer-undercounted inside scans; see
    the accounting pass)
  * an ACCOUNTING pass: the same step unrolled with n_scan=1 and n_scan=2
    layers (periods) and single-chunk attention; the L2-L1 delta gives
    exact per-layer HLO FLOPs / bytes / collective-bytes, from which
    full-depth totals are reconstructed:
        total = L1 + (n_scan - 1) * (L2 - L1)
    (wkv/mamba time-recurrences remain while-loops even unrolled; their
    FLOPs are added analytically in benchmarks/roofline.py.)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--skip-acct]
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, SHAPES, cells_for, get_config,
                           input_specs)
from repro.distributed import sharding as shd
from repro.launch import hlo_analysis as hla
from repro.launch.mesh import dist_for, make_production_mesh, set_mesh
from repro.models import model as model_lib
from repro.optim import adafactor_init, adamw_init
from repro.train.steps import make_decode_step, make_prefill_step, \
    make_train_step

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _opt_specs(cfg, p_specs, p_shapes, dist=None):
    """Optimizer-state PartitionSpecs mirroring the param specs
    (ZeRO-2 shards the moments over data even when params are not)."""
    from jax.sharding import PartitionSpec as P
    if dist is not None and cfg.zero == 2 and cfg.optimizer == "adamw":
        m = jax.tree.map(
            lambda sp, sh: shd.opt_extra_shard(cfg, dist, sp, sh),
            p_specs, p_shapes, is_leaf=lambda x: isinstance(x, P))
        return {"step": P(), "m": m, "v": m}
    if cfg.optimizer == "adafactor":
        def fac(spec, shp):
            if shp.ndim >= 2:
                return {"vr": P(*spec[:len(spec) - 1] if len(spec) else ()),
                        "vc": P(*(list(spec[:-2]) + [spec[-1]])
                                if len(spec) >= 2 else spec)}
            return {"v": spec}
        v = jax.tree.map(fac, p_specs, p_shapes,
                         is_leaf=lambda x: isinstance(x, P))
        return {"step": P(), "v": v}
    return {"step": P(), "m": p_specs, "v": p_specs}


def _opt_shapes(cfg, p_shapes):
    init = adafactor_init if cfg.optimizer == "adafactor" else adamw_init
    return jax.eval_shape(init, p_shapes)


def lower_cell(cfg, shape, mesh, *, donate=True):
    """Lower + compile one cell on one mesh. Returns (compiled, lowered)."""
    from jax.sharding import PartitionSpec as P
    dist = dist_for(mesh)
    p_specs, p_shapes = shd.param_specs(cfg, dist)
    b_specs, b_shapes = shd.batch_specs(cfg, shape, dist)

    with set_mesh(mesh):
        if shape.kind == "train":
            o_specs = _opt_specs(cfg, p_specs, p_shapes, dist)
            o_shapes = _opt_shapes(cfg, p_shapes)
            fn = make_train_step(cfg, dist)
            jfn = jax.jit(
                fn,
                in_shardings=(p_specs, o_specs, b_specs, P()),
                out_shardings=(p_specs, o_specs, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jfn.lower(p_shapes, o_shapes, b_shapes,
                                jax.ShapeDtypeStruct((), jnp.int32))
        elif shape.kind == "prefill":
            fn = make_prefill_step(cfg, dist)
            c_specs, _ = shd.cache_specs(cfg, shape, dist)
            jfn = jax.jit(fn, in_shardings=(p_specs, b_specs),
                          out_shardings=(shd.logits_spec(
                              cfg, dist, shape.global_batch), c_specs))
            lowered = jfn.lower(p_shapes, b_shapes)
        else:                                          # decode
            fn = make_decode_step(cfg, dist)
            c_specs, c_shapes = shd.cache_specs(cfg, shape, dist)
            jfn = jax.jit(
                fn,
                in_shardings=(p_specs, c_specs, b_specs["token"],
                              b_specs["pos"]),
                out_shardings=(shd.logits_spec(
                    cfg, dist, shape.global_batch), c_specs),
                donate_argnums=(1,) if donate else ())
            lowered = jfn.lower(p_shapes, c_shapes, b_shapes["token"],
                                b_shapes["pos"])
        compiled = lowered.compile()
    return compiled, lowered


def _acct_cfg(cfg, shape, n_periods):
    """Config for the FLOP-accounting pass: n_periods periods, unrolled,
    single-chunk attention."""
    _, _, period = model_lib._stack_plan(cfg)
    n_layers = cfg.first_dense + n_periods * period
    return dataclasses.replace(
        cfg, n_layers=n_layers, unroll=True,
        attn_chunk=max(shape.seq_len, 1),
        # MoE capacity depends only on tokens/experts; unchanged.
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             *, skip_acct=False, verbose=True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "n_devices": mesh.size, "ok": False}
    t0 = time.time()
    try:
        compiled, lowered = lower_cell(cfg, shape, mesh)
        rec["memory"] = hla.memory_stats(compiled)
        rec["cost"] = hla.cost_stats(compiled)
        coll = hla.parse_collectives(compiled.as_text())
        rec["collectives"] = coll.by_op()
        rec["collective_link_bytes"] = coll.total_link_bytes
        rec["ok"] = True
        del compiled, lowered
    except Exception as e:  # noqa: BLE001 - record the failure verbatim
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["compile_s"] = round(time.time() - t0, 1)

    if rec["ok"] and not skip_acct and mesh_kind == "single":
        try:
            acct = {}
            for n in (1, 2):
                c2, _ = lower_cell(_acct_cfg(cfg, shape, n), shape, mesh,
                                   donate=False)
                acct[n] = {
                    "cost": hla.cost_stats(c2),
                    "coll_link_bytes":
                        hla.parse_collectives(c2.as_text()).total_link_bytes,
                }
                del c2
            _, n_scan, _ = model_lib._stack_plan(cfg)
            d_fl = acct[2]["cost"]["flops"] - acct[1]["cost"]["flops"]
            d_by = (acct[2]["cost"]["bytes_accessed"]
                    - acct[1]["cost"]["bytes_accessed"])
            d_cl = (acct[2]["coll_link_bytes"] - acct[1]["coll_link_bytes"])
            rec["acct"] = {
                "L1": acct[1], "L2": acct[2],
                "per_layer_flops": d_fl,
                "per_layer_bytes": d_by,
                "per_layer_coll_link_bytes": d_cl,
                "total_flops": acct[1]["cost"]["flops"] + (n_scan - 1) * d_fl,
                "total_bytes": acct[1]["cost"]["bytes_accessed"]
                + (n_scan - 1) * d_by,
                "total_coll_link_bytes":
                    acct[1]["coll_link_bytes"] + (n_scan - 1) * d_cl,
            }
        except Exception as e:  # noqa: BLE001
            rec["acct_error"] = f"{type(e).__name__}: {e}"

    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / f"{arch}__{shape_name}__{mesh_kind}.json"
    out.write_text(json.dumps(rec, indent=1))
    if verbose:
        mem = rec.get("memory", {})
        print(f"[{rec['compile_s']:7.1f}s] {arch:22s} {shape_name:12s} "
              f"{mesh_kind:6s} ok={rec['ok']} "
              f"temp/dev={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"args/dev={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB",
              flush=True)
        if not rec["ok"]:
            print("  ERROR:", rec["error"], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-acct", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]

    total = ok = 0
    for arch in archs:
        cfg = get_config(arch)
        for cell in cells_for(cfg):
            if args.shape and cell.shape.name != args.shape:
                continue
            if not cell.run:
                print(f"[  skip ] {arch:22s} {cell.shape.name:12s} "
                      f"-- {cell.skip_reason}", flush=True)
                continue
            for mk in meshes:
                out = RESULTS / f"{arch}__{cell.shape.name}__{mk}.json"
                if args.skip_existing and out.exists() and \
                        json.loads(out.read_text()).get("ok"):
                    continue
                rec = run_cell(arch, cell.shape.name, mk,
                               skip_acct=args.skip_acct)
                total += 1
                ok += rec["ok"]
    print(f"dry-run complete: {ok}/{total} cells compiled", flush=True)


if __name__ == "__main__":
    main()
