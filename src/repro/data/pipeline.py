"""Deterministic synthetic token pipeline.

Stateless-by-step: ``batch_at(step)`` is a pure function of (seed, step,
shape), so resume-after-restart is bitwise identical with no iterator
state to checkpoint, and each data-parallel rank can slice its shard
locally (`host_slice`). Sequences are Zipf-ish token draws with repeated
n-gram structure so the LM loss actually decreases during the examples'
short training runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1


def _zipf_tokens(key, shape, vocab, alpha):
    u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
    # inverse-CDF approximation of a Zipf over [0, vocab)
    ranks = jnp.power(u, -1.0 / (alpha - 1.0)) - 1.0
    return jnp.clip(ranks.astype(jnp.int32), 0, vocab - 1)


def batch_at(cfg: DataConfig, step: int | jax.Array) -> dict:
    """Global batch for `step`: tokens + next-token targets."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2 = jax.random.split(key)
    B, T = cfg.global_batch, cfg.seq_len
    toks = _zipf_tokens(k1, (B, T + 1), cfg.vocab, cfg.zipf_alpha)
    # inject learnable bigram structure: every even position repeats the
    # previous token with a fixed offset
    pos = jnp.arange(T + 1)
    prev = jnp.roll(toks, 1, axis=1)
    structured = jnp.where((pos[None, :] % 2 == 0),
                           (prev * 31 + 7) % cfg.vocab, toks)
    return {"tokens": structured[:, :-1],
            "targets": structured[:, 1:]}


def host_slice(batch: dict, rank: int, n_ranks: int) -> dict:
    """The per-host slice of a global batch (multi-host deployment)."""
    def sl(x):
        per = x.shape[0] // n_ranks
        return x[rank * per:(rank + 1) * per]
    return jax.tree.map(sl, batch)
