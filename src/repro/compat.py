"""Version compatibility for the narrow set of new-jax APIs this repo
uses, so the same source runs on the container's older jax as well.

* shard_map: ``jax.shard_map`` (new) vs ``jax.experimental.shard_map``
  (old; ``check_vma`` was named ``check_rep``).
* tpu_compiler_params: ``pltpu.CompilerParams`` (new) vs
  ``pltpu.TPUCompilerParams`` (old). Resolved lazily so shard_map
  consumers (models, distributed) never pull in Pallas-TPU.
* set_mesh lives in launch/mesh.py (kept there: importing that module
  must not touch jax device state).
"""
from __future__ import annotations

import jax


def tpu_compiler_params(**kwargs):
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or pltpu.TPUCompilerParams
    return cls(**kwargs)

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
