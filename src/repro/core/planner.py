"""Hull-bucketing sweep planner: partition a heterogeneous-site sweep
into a few padded hulls so compute stops scaling with the WORST site.

Why
---
``make_multi_site_batch`` runs arbitrary FBSite mixes as one vmapped
compile by padding every scenario to a single hull — the per-axis max
over the batch. That is perfect for compile count (one) but terrible for
compute once site sizes diverge: a 2x4-rack toy site padded into a
4x32-rack hull steps ~30x more state than it needs, every tick, for
every scenario. Wide design-space sweeps (the Fig 1 axis) are exactly
the mixes where hulls explode.

The planner splits the sweep into K buckets — K compiles instead of
one — chosen so the total *padded cost* (estimated step cost of the
bucket hull x scenarios in the bucket) is small, under a caller-set
``max_compiles`` budget. ``simulator.run_sweep_planned`` then executes
the buckets as an async pipeline — dispatched in ``dispatch_order``
(largest padded cost first, so later buckets' trace/compile overlaps
the big bucket's device execution), each bucket an ordinary
``make_multi_site_batch`` + chunk dispatch, so the one-trace-per-(hull,
batch-shape, chunk) contract holds per bucket — and merges results back
into caller order.

Cost model
----------
``site_cost(site)`` estimates the per-scenario, per-tick compute of the
compiled step on a hull, as a weighted sum of the step's dense-array
footprints (the step is bandwidth-bound elementwise work, so array
elements touched is the right first-order proxy):

* edge tier — dominant: per-rack flow state (R x F_SLOTS, ~4 arrays of
  it live per tick) plus the per-rack uniform draws;
* RSW tier — (R, planes) queue pair, plane weights, down-queue views;
* CSW tier — (NC, csw_uplinks) uplink queues and (NC, racks_per_cluster)
  down queues, each touched a few times;
* FC tier — (n_fc, NC) down queues.

The units are arbitrary; only RATIOS matter (bucket A vs bucket B vs
the single hull), so constant factors common to all hulls cancel.
``padded_cost(bucket) = site_cost(hull(bucket)) * len(bucket)`` and the
waste is ``1 - ideal/padded`` where ideal charges each scenario its own
site's cost. These are the padding-waste stats surfaced per bucket in
the plan report (and uploaded as a CI artifact by the canaries job).

``plan_sites(..., cost_model="hlo")`` swaps the hand model for the
blessed XLA ``cost_analysis()`` measurements in the artifact-contract
file (``repro.analysis.artifact.hlo_cost_table`` — a committed-file
read, no jax import): exact hull hits use measured flops/tick/scenario,
unmeasured hulls fall back to ``site_cost`` rescaled by the table's
geometric-mean measured/model ratio so mixed exact/fallback buckets
stay comparable. The default (``cost_model="model"``) path is
untouched — same function object, bit-identical bucketing — and the
artifact audit's calibration check (RL007) pins the hand model's
ratio spread against the same measurements, so drift between the two
models is caught in CI rather than silently skewing plans.

Algorithm
---------
Scenarios with identical FBSites are grouped first (they pad to nothing
inside their own bucket). If the number of distinct sites fits the
budget, every distinct site gets its own exact-hull bucket — merging
can only grow a hull, so more buckets are never costlier; the budget
exists because each bucket pays a compile. Over budget, buckets are
merged agglomeratively: repeatedly merge the pair whose merged padded
cost exceeds the pair's current costs by the least, until the budget is
met. (Optimal bucketing is a set-partition problem — NP-hard in
general; greedy pairwise merging is the standard Ward-style heuristic
and is exact for the common bimodal small-vs-large mixes.)

``SweepPlan.fingerprint`` hashes the bucket assignment + every bucket
hull; benchmarks/simcache.py folds it into its cache key so planned and
unplanned runs never serve each other stale results.

K=1 degenerate case: one bucket, hull == the per-axis max over all
sites — bit-identical to the plain ``make_multi_site_batch`` path
(tests/test_planner.py pins the parity).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from dataclasses import dataclass
from typing import Sequence

from repro.core.topology import FBSite, full_site_tag, pad_hull

#: must match simulator.F_SLOTS (the per-rack flow-slot count, the
#: dominant edge-tier array width); asserted in tests/test_planner.py
#: so the two cannot drift silently. Defined here (not imported) to
#: keep the planner importable without pulling in jax.
FLOW_SLOTS = 64

#: bump when the cost model or bucketing algorithm changes: the
#: fingerprint feeds cache keys, so plans from an older planner must
#: not collide with new ones
PLAN_SCHEMA_VERSION = 1


def site_cost(site: FBSite) -> float:
    """Estimated per-scenario, per-tick step cost on ``site`` (arbitrary
    units — see the module docstring's cost model)."""
    R, P = site.n_racks, site.csw_per_cluster
    NC, CUP = site.n_csw, site.csw_uplinks
    RPC, NF = site.racks_per_cluster, site.n_fc
    edge = R * (4.0 * FLOW_SLOTS + 8.0)
    rsw = 6.0 * R * P
    csw = NC * (3.0 * CUP + 4.0 * RPC)
    fc = 3.0 * NF * NC
    return edge + rsw + csw + fc


@dataclass(frozen=True)
class PlanBucket:
    """One compile unit: the scenarios at caller positions ``indices``
    run together padded to ``hull``."""
    indices: tuple          # caller positions, ascending
    hull: FBSite
    padded_cost: float      # site_cost(hull) * len(indices)
    ideal_cost: float       # sum of the members' own site_costs

    @property
    def waste_frac(self) -> float:
        """Fraction of this bucket's compute spent on hull padding."""
        return 1.0 - self.ideal_cost / max(self.padded_cost, 1e-12)


@dataclass(frozen=True)
class SweepPlan:
    buckets: tuple          # PlanBucket, ordered by first caller index
    max_compiles: int
    single_hull_cost: float  # the K=1 reference: cost(hull(all)) * N

    @property
    def n_scenarios(self) -> int:
        return sum(len(b.indices) for b in self.buckets)

    @property
    def padded_cost(self) -> float:
        return sum(b.padded_cost for b in self.buckets)

    @property
    def ideal_cost(self) -> float:
        return sum(b.ideal_cost for b in self.buckets)

    @property
    def waste_frac(self) -> float:
        return 1.0 - self.ideal_cost / max(self.padded_cost, 1e-12)

    @property
    def savings_vs_single_hull_frac(self) -> float:
        """Padded-compute cut vs running everything in one hull (the
        pre-planner path); 0 for K=1 by construction."""
        return 1.0 - self.padded_cost / max(self.single_hull_cost, 1e-12)

    @property
    def dispatch_order(self) -> tuple:
        """Bucket indices in descending padded-cost order — the async
        pipeline's dispatch schedule (simulator.run_sweep_planned): the
        most expensive bucket launches first so the cheaper buckets'
        trace/compile time overlaps its device execution. Ties break on
        the caller-order bucket index, keeping the order deterministic
        (result order is unaffected: fetches merge by caller index)."""
        return tuple(sorted(
            range(len(self.buckets)),
            key=lambda k: (-self.buckets[k].padded_cost, k)))

    @property
    def fingerprint(self) -> str:
        """Stable hash of (bucket assignment, bucket hulls) — the cache
        namespace for planned results (benchmarks/simcache.py)."""
        blob = json.dumps(
            {"schema": PLAN_SCHEMA_VERSION,
             "buckets": [{"idx": list(b.indices),
                          "hull": dataclasses.astuple(b.hull)}
                         for b in self.buckets]},
            sort_keys=True)
        return hashlib.sha1(blob.encode()).hexdigest()[:16]

    def bucket_tag(self, k: int) -> str:
        """Stable per-bucket checkpoint-tag component: bucket index +
        the plan fingerprint, so checkpoints from different plans (or
        different buckets of one plan) sharing a directory never
        collide — the durable executor (simulator.run_sweep_planned)
        appends this to the caller's CheckpointSpec tag."""
        return f"b{k:02d}-{self.fingerprint[:8]}"

    def report(self) -> dict:
        """JSON-ready padding-waste report (per bucket + totals)."""
        return {
            "plan_schema": PLAN_SCHEMA_VERSION,
            "max_compiles": self.max_compiles,
            "n_buckets": len(self.buckets),
            "n_scenarios": self.n_scenarios,
            "padded_cost": self.padded_cost,
            "ideal_cost": self.ideal_cost,
            "waste_frac": self.waste_frac,
            "single_hull_cost": self.single_hull_cost,
            "savings_vs_single_hull_frac": self.savings_vs_single_hull_frac,
            "dispatch_order": list(self.dispatch_order),
            "fingerprint": self.fingerprint,
            "buckets": [{
                "hull": full_site_tag(b.hull),
                "n_scenarios": len(b.indices),
                "indices": list(b.indices),
                "padded_cost": b.padded_cost,
                "ideal_cost": b.ideal_cost,
                "waste_frac": b.waste_frac,
            } for b in self.buckets],
        }


def hlo_cost_fn(cost_table: dict | None = None):
    """Cost function backed by the blessed HLO measurements.

    ``cost_table`` is ``repro.analysis.artifact.hlo_cost_table()``
    output (loaded from the committed contract file when omitted):
    ``full_site_tag -> {"flops_per_tick_scen", "site"}``. Exact hull
    hits return the measured flops; anything unmeasured falls back to
    ``site_cost`` scaled by the table's geometric-mean measured/model
    ratio, so exact and fallback costs share one unit system. An empty
    table degenerates to plain ``site_cost`` (ratio 1).
    """
    if cost_table is None:
        from repro.analysis.artifact import hlo_cost_table
        cost_table = hlo_cost_table()
    log_sum, n = 0.0, 0
    for entry in cost_table.values():
        model = site_cost(entry["site"])
        if model > 0.0 and entry["flops_per_tick_scen"] > 0.0:
            log_sum += math.log(entry["flops_per_tick_scen"] / model)
            n += 1
    ratio = math.exp(log_sum / n) if n else 1.0

    def cost(site: FBSite) -> float:
        entry = cost_table.get(full_site_tag(site))
        if entry is not None:
            return float(entry["flops_per_tick_scen"])
        return ratio * site_cost(site)

    return cost


def plan_sites(sites: Sequence[FBSite], max_compiles: int = 4, *,
               cost_model: str = "model",
               cost_table: dict | None = None) -> SweepPlan:
    """Partition scenario sites into <= ``max_compiles`` hull buckets.

    ``sites[i]`` is scenario i's FBSite (caller order). Every index
    lands in exactly one bucket (tests/test_planner.py holds a
    hypothesis property to that effect).

    ``cost_model`` selects the bucketing cost function: ``"model"``
    (default) is the hand model ``site_cost`` — bit-identical to the
    pre-``cost_model`` planner — and ``"hlo"`` uses the blessed
    ``cost_analysis()`` measurements via ``hlo_cost_fn(cost_table)``
    (``cost_table`` defaults to the committed contract file; pass one
    explicitly to avoid the file read or to test synthetic tables).
    """
    if cost_model == "model":
        cost = site_cost
    elif cost_model == "hlo":
        cost = hlo_cost_fn(cost_table)
    else:
        raise ValueError(
            f"cost_model must be 'model' or 'hlo', got {cost_model!r}")
    sites = list(sites)
    if not sites:
        raise ValueError("plan_sites: empty site list")
    if max_compiles < 1:
        raise ValueError(f"max_compiles must be >= 1, got {max_compiles}")

    # group scenarios on identical sites: they pad to nothing together
    groups: dict[FBSite, list[int]] = {}
    for i, s in enumerate(sites):
        groups.setdefault(s, []).append(i)
    # work items: (distinct member sites, caller indices)
    work = [([s], idx) for s, idx in groups.items()]

    def padded(members, idx):
        return cost(pad_hull(members)) * len(idx)

    # agglomerative merge until the compile budget is met: each round
    # fuse the pair whose merged hull costs the least extra
    while len(work) > max_compiles:
        best = None
        for a in range(len(work)):
            for b in range(a + 1, len(work)):
                ma, ia = work[a]
                mb, ib = work[b]
                delta = (padded(ma + mb, ia + ib)
                         - padded(ma, ia) - padded(mb, ib))
                if best is None or delta < best[0]:
                    best = (delta, a, b)
        _, a, b = best
        ma, ia = work[a]
        mb, ib = work[b]
        work[a] = (ma + mb, ia + ib)
        work.pop(b)

    buckets = []
    for members, idx in work:
        hull = pad_hull(members)
        idx = tuple(sorted(idx))
        buckets.append(PlanBucket(
            indices=idx, hull=hull,
            padded_cost=cost(hull) * len(idx),
            ideal_cost=sum(cost(sites[i]) for i in idx)))
    buckets.sort(key=lambda b: b.indices[0])
    return SweepPlan(
        buckets=tuple(buckets), max_compiles=max_compiles,
        single_hull_cost=cost(pad_hull(sites)) * len(sites))
