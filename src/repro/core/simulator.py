"""LC/DC network simulator: 1 us-slotted, fully vectorized, lax.scan-jitted.

Models the Fig 2 Facebook-style site end to end:

  server NICs --(node-gated links)--> RSW --(stage-gated uplinks)--> CSW
      --(stage-gated 40G uplinks)--> FC --> CSW --> RSW --> server

Edge traffic is stochastic (per-rack flow slots driven by core/traffic.py:
lognormal sizes, ON/OFF bursts); the aggregation tiers are fluid (float
packet counts) which preserves the queue dynamics that drive the
watermark controller while keeping the whole site one dense-array state.

Down-routing honours the stage invariant: packets that land on a CSW/FC
whose downlink to the destination is gated off migrate over the cluster /
FC load-balancing rings (the rings exist for exactly this in Fig 2) to
the always-on stage-1 path, paying ring latency. Connectivity is never
lost because stage >= 1 everywhere (the paper's core invariant).

The wiring invariants are FBSite's (topology.py): RSW uplink c IS the
link to cluster-CSW c (the stage-c plane, ``rsw_uplinks ==
csw_per_cluster``) and CSW uplink f IS the link to fabric core f
(``csw_uplinks == n_fc``). The hot loop's down-plane reshapes are
written against the semantically correct axes (csw_per_cluster for the
plane axis, csw_uplinks for the FC-uplink axis), so topology-general
sites — any n_clusters / racks_per_cluster / csw_per_cluster / n_fc —
route correctly.

Latency is measured with Little's law per queue group (mean delay =
mean backlog / delivered rate) plus fixed per-hop wire/pipeline/stack
latencies; the paper reports mean packet delivery latency, which this
estimates directly.

In-scan packet-delay distributions
----------------------------------
The paper's headline tradeoff ("60% power saved at the cost of 6%
higher delay") is a statement about *distributions*, not just means:
the laser/CDR wake stall behind ``STAGE_UP_DELAY`` shows up in the
latency TAIL. Every tick the step therefore draws one delay sample per
rack and destination class (intra-cluster / inter-cluster), weighted by
the packets injected there that tick:

    d = STACK_US + hops * WIRE_HOP_US        (fixed path cost)
      + enq_wait(RSW) + down_wait(CSW->rack) (queueing, kernel-fed)
      [+ enq_wait(CSW up) + fc_wait]         (inter-cluster only)
      + wake_stall(RSW) [+ wake_stall(CSW)]  (gating-attributed)

The queue-wait terms come from the SAME oracle-checked kernel as the
datapath: ``ops.switch_step`` emits per-switch backlog-age (``enq_wait``,
what an arrival queues behind) and post-serve occupancy moments. The
wake-stall terms are ``gating.wake_stall_ticks`` — the remaining ticks
of an in-flight stage-up — so with gating disabled the attribution is
exactly zero. Ring-detour hops are attributed separately in
``_finalize`` from the ring counters (a scalar mean, not in the
histogram).

Samples are binned into a fixed log-spaced histogram
(``constants.DELAY_HIST_BINS`` = 48 bins; bin 0 is
[0, DELAY_HIST_MIN_US); bin i covers [MIN * 2**((i-1)/BPO),
MIN * 2**(i/BPO)) with BPO = DELAY_HIST_BINS_PER_OCTAVE = 6; the last
bin absorbs overflow; edges in ``DELAY_BIN_EDGES_US``). The histogram
is an ordinary accumulator: folded into the device-resident fold
buffer at chunk boundaries like every other one (see
"Device-resident execution" below), so memory stays bounded for
arbitrarily long runs. ``_finalize`` extracts log-interpolated ``delay_p50_us`` /
``delay_p95_us`` / ``delay_p99_us``, the normalized ``delay_hist``,
and the attribution split ``delay_queue_us`` (queueing) /
``delay_wake_stall_us`` (STAGE_UP_DELAY stalls) / ``delay_ring_us``
(ring-detour hops), plus ``wake_stall_frac`` (fraction of sampled
packets that arrived during a stage-up) and per-tier occupancy
mean/variance from the kernel's moment outputs.

``delay_mean_sampled_us`` (the histogram's own mean) and
``mean_latency_us`` (Little's law) are different estimators of the same
quantity and deliberately both reported: the first carries attribution
and tails, the second is the paper's original headline metric.

Flow-level workload engine (flow_mode=1)
----------------------------------------
``flow_mode=1`` replaces the rate-based edge with a flow abstraction
inside the same jitted scan: a fixed-capacity per-rack flow table
(``C.FLOW_TABLE_SLOTS`` static slots; the traced ``flow_table_cap``
knob bounds the usable prefix) holding arrival tick, remaining
packets, destination class and an AIMD congestion window per flow.
Flow sizes are sampled in-scan from the heavy-tailed
websearch/datamining CDFs of core/workloads.py (``flow_size_dist``);
arrivals are per-rack Bernoulli events (``flow_arrival_rate``, default
derived from the trace so both modes offer comparable load) spawning
``incast_degree`` same-destination flows at once; table overflow is
EVICTION, counted so started == completed + evicted + in-flight stays
exact (the ``validate=True`` guard checks it per chunk). Completions
bin into per-size-class (short/medium/long) FCT and FCT-slowdown
histograms riding the same log-spaced machinery as the delay
histogram; the path-delay part of each FCT is the tick's d_i/d_x
sample, so wake/fault stalls attribute into FCT through the one
``gating.stall_attribution`` seam. All of it is jnp.where-selected
against the rate-based path — zero new compile sites, and
``flow_mode=0`` is BIT-IDENTICAL to the pre-flow engine (the fault
knobs' zero-knob discipline: dedicated fold_in branches, fixed draw
widths, masked accumulator adds; tests/test_flows.py pins it against
committed goldens).

Batched multi-scenario sweeps
-----------------------------
Every per-scenario knob — the TrafficSpec fields, ``gating_enabled``,
``rate_scale``, the watermarks, the anti-flap dwell, the seed — is an
array-valued leaf of a :class:`Scenario` pytree, so one jitted
``lax.scan`` step is ``vmap``-ped over an arbitrary batch of scenarios:

    batch = sweep_grid(traces=("fb_hadoop", "fb_web"), seeds=(0, 1))
    results = run_sweep(batch, n_ticks=100_000)   # list of metric dicts

Multi-site batches
------------------
The scenario's site SHAPE is itself a set of traced knobs: ``Scenario``
carries each scenario's real (n_clusters, racks_per_cluster,
csw_per_cluster, n_fc, servers_per_rack), and the step runs on a static
padded hull (the per-axis max over the batch) with validity masks
derived in-step. ``make_multi_site_batch`` stacks runs on ARBITRARY
FBSite variants — the Fig 1 design-comparison axis — into one batch
that compiles ONCE. Racks and CSWs occupy blocked (cluster-major)
positions in the hull, padded entries are provably inert (no spawns, no
arrivals, stage pinned to 1, masked out of every accumulator), and all
per-rack randomness is keyed by the rack's logical id, so a site's
metrics are identical whether it runs alone at exact dims or padded
inside a heterogeneous batch.

Padding to ONE hull wastes compute once site sizes diverge (every
scenario steps the worst site's state). ``run_sweep_planned`` fixes
that: it partitions the runs into a few hull buckets via the
cost-model planner in core/planner.py (``max_compiles`` budget), runs
each bucket as its own tight-hull batch, and merges results back in
caller order with per-bucket padding-waste stats — same metrics
(1e-3-pinned parity), a fraction of the padded compute.

One-compile contract: ``run_sweep`` compiles exactly once per
(hull topology, batch size, chunk length) — re-running the same-shaped
sweep with different knob values (traces, watermarks, seeds, sites
fitting the same hull, ...) reuses the cached executable;
``TRACE_COUNT`` counts step traces so tests can pin this. Long runs are
chunked (``chunk_ticks``, default 10k): the jitted chunk donates its
carry on accelerator backends. A remainder (``n_ticks % chunk_ticks !=
0``) does NOT compile a second program: the tail runs the same
fixed-length chunk with a live mask, dead ticks passing the carry
through unchanged.

Device-resident execution
-------------------------
The per-chunk accumulator fold happens ON DEVICE, inside the same
jitted chunk program as the scan: a per-scenario fold buffer (float64
where the backend enables x64, otherwise a compensated Kahan float32
``(sum, comp)`` pair) absorbs each chunk's accumulators and the in-scan
accumulators are re-zeroed, all without leaving the device. The chunk
loop is therefore pure async dispatch — no host synchronization at
chunk boundaries — and the entire run performs exactly ONE host
transfer (the final fold fetch; ``HOST_TRANSFER_COUNT`` counts these so
benchmarks/bench_sweep.py can gate it). Kahan compensation bounds the
cross-chunk float32 accumulation error at O(eps) independent of chunk
count, so device-fold metrics match the legacy host-fold path
(``fold="host"``: per-chunk ``device_get`` + float64 numpy fold, kept
for parity pinning) to <= 1e-6 relative.

The scenario batch axis additionally shards across all local devices
(``shard=None`` auto-enables when >1 device is visible; CPU CI
exercises it with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
Scenario knobs, sim state and fold buffers are placed with a
``NamedSharding`` over the batch axis; batches that don't divide the
device count are padded with copies of scenario 0 and the pad rows
dropped before finalization — scenarios are independent vmap lanes, so
padding and sharding are bit-inert for every real scenario's metrics
(tests/test_sharding.py pins this on 4 fake devices).

``run_sweep_planned`` pipelines its hull buckets: every bucket's chunk
programs are DISPATCHED first (largest padded cost first, the planner's
``dispatch_order``, so tracing/compiling bucket k+1 overlaps device
execution of bucket k), and results are fetched afterwards — one
blocking transfer per bucket, no interleaved blocking. Caller-order
results, ``plan_bucket``/``plan_hull`` annotation and the
one-trace-per-(hull, batch-shape, chunk) contract are preserved
(``pipeline=False`` recovers strictly serial bucket execution,
bit-identically).

The per-switch scheduling/enqueue/serve/watermark block of the hot loop
runs through ``ops.switch_step`` — the Pallas kernel on TPU, its
pure-jnp oracle (kernels/ref.py) on CPU — so the simulator and the
kernel share one switch-tick definition (including the multi-site
``valid`` padding mask).

``run_sim`` (one scenario) is kept for unit runs and ablations; it
re-traces per call exactly like the pre-sweep engine, so serial loops
over scenarios pay compile each time — use ``run_sweep`` for sweeps.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from dataclasses import dataclass
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import checkpoint as _ckpt
from repro.core import constants as C
from repro.core import gating
from repro.core import workloads
from repro.core.checkpoint import (CheckpointError,  # noqa: F401 — re-export
                                   CheckpointSpec)
from repro.core.topology import (FBSite, full_site_tag, pad_hull,
                                 site_tag)
from repro.core.traffic import (TRAFFIC_SPECS, TrafficSpec,
                                flow_arrival_rate_per_tick,
                                rack_flow_rate_per_tick, stack_specs)
from repro.kernels import ops

F_SLOTS = 64              # concurrent flow slots per rack
MAX_FAULT_LINKS = 16      # fixed per-switch fault-draw width: hull link
#                           axes must fit so the uniform block's shape
#                           (and thus every draw) is padding-invariant
NODE_IDLE_TICKS = 50      # server-link idle timeout (us)
# ring migration budgets are per-site (1 pkt/tick per 10G ring link):
# scen.csw_ring / scen.fc_ring, from FBSite.csw_ring_links/fc_ring_links
WIRE_HOP_US = 0.5         # fiber + switch pipeline per hop
STACK_US = 3.75           # TCP/IP + NIC (Sec IV-C)

CHUNK_TICKS = 10_000      # default scan chunk (accumulator fold period)

#: bump when the step semantics change — cached results keyed on an
#: older version (benchmarks/simcache.py) are invalidated
#: (v3: in-scan delay histograms + wake-stall attribution, corrected
#: half-open on_frac_hist buckets; v4: hull-bucketed planned sweeps —
#: results carry plan_bucket/plan_hull, caches carry the plan
#: fingerprint; v5: device-resident accumulator fold + scenario-axis
#: sharding — caches additionally carry the execution mode; v6: optical
#: fault-injection subsystem — fault knobs are Scenario leaves, results
#: gain delivered/fault-drop/retry/connectivity metrics, and cache meta
#: carries the fault fingerprint + validate flag so fault-free cached
#: results never alias faulted runs; v7: flow-level workload engine —
#: flow knobs are Scenario leaves, results gain flow/FCT metrics, and
#: cache meta carries the flow fingerprint so flow-free cached results
#: never alias flow runs; v8: correlated failure domains — the
#: per-plane hard-fault hazard ``plane_fail_prob`` is a Scenario leaf
#: joined into the fault fingerprint, so plane-fault-free cached
#: results never alias correlated-fault runs)
SIM_SCHEMA_VERSION = 8

#: number of times the sweep step has been traced (the one-compile probe)
TRACE_COUNT = 0

#: optional per-trace attribution seam: when set to a callable it is
#: invoked with the static ``site`` hull at every sweep-step trace
#: (same trace-time-only side effect as TRACE_COUNT). The runtime
#: sanitizer (repro.analysis.sanitizer.TraceLedger) uses it to pin the
#: planner pipeline's one-trace-per-bucket contract per hull tag.
TRACE_HOOK = None

#: number of accumulator host transfers the sweep engine has performed
#: (``device_get`` of fold buffers / in-scan accumulators). The
#: device-resident fold path does exactly ONE per run_sweep (one per
#: planned bucket); the legacy ``fold="host"`` path does one per chunk.
#: benchmarks/bench_sweep.py gates transfers-per-bucket <= 1 on this.
HOST_TRANSFER_COUNT = 0

#: scalar metrics that must agree between run_sim and run_sweep — the
#: shared contract checked by tests/test_sweep.py and the
#: benchmarks/bench_sweep.py parity canary
PARITY_KEYS = (
    "mean_latency_us", "injected_pkts", "delivered_pkts", "drop_frac",
    "switch_energy_savings_frac", "rsw_link_on_frac", "csw_link_on_frac",
    "node_link_on_frac", "transceiver_power_w", "half_off_frac",
    "delay_p50_us", "delay_p99_us", "delay_queue_us",
    "delay_wake_stall_us", "delivered_frac", "fault_drop_frac",
    "delay_fault_stall_us", "flows_completed", "flow_evicted_frac",
    "fct_slowdown_p99",
)


def worst_parity(ref_results, new_results):
    """Worst relative PARITY_KEYS divergence between two result lists
    (zipped pairwise); returns (diff, "label:key"). The one scan every
    parity canary shares."""
    worst_key, worst = None, 0.0
    for r_a, r_b in zip(ref_results, new_results):
        for k in PARITY_KEYS:
            a, b = r_a[k], r_b[k]
            d = abs(a - b) / max(abs(a), abs(b), 1e-9)
            if d > worst:
                worst_key, worst = f"{r_b['label']}:{k}", d
    return worst, worst_key

#: histogram bin edges in us (len DELAY_HIST_BINS + 1; see module
#: docstring). Bin i covers [edge[i], edge[i+1]); the last bin also
#: absorbs anything beyond the final edge.
def _log_bin_edges(min_val: float, bins: int, bpo: float) -> np.ndarray:
    """Edges of a log-spaced histogram frame (len bins + 1): bin 0 is
    linear [0, min_val); bin i >= 1 covers [min * 2**((i-1)/bpo),
    min * 2**(i/bpo)); the last bin absorbs overflow."""
    return np.concatenate([
        [0.0],
        min_val * 2.0 ** (np.arange(bins, dtype=np.float64) / bpo)])


DELAY_BIN_EDGES_US = _log_bin_edges(
    C.DELAY_HIST_MIN_US, C.DELAY_HIST_BINS, C.DELAY_HIST_BINS_PER_OCTAVE)
#: the flow engine's FCT / FCT-slowdown frames (same machinery, wider
#: dynamic range; see constants.py)
FCT_BIN_EDGES_US = _log_bin_edges(
    C.FCT_HIST_MIN_US, C.FCT_HIST_BINS, C.FCT_HIST_BINS_PER_OCTAVE)
FCT_SLOWDOWN_BIN_EDGES = _log_bin_edges(
    C.FCT_SLOWDOWN_HIST_MIN, C.FCT_SLOWDOWN_HIST_BINS,
    C.FCT_SLOWDOWN_HIST_BINS_PER_OCTAVE)


def _delay_hist_add(hist, d, w, *, min_val=C.DELAY_HIST_MIN_US,
                    bpo=C.DELAY_HIST_BINS_PER_OCTAVE,
                    bins=C.DELAY_HIST_BINS):
    """Bin weighted delay samples into a log-spaced histogram.

    d, w: (N,) sample values (us) and packet weights. Dense one-hot
    accumulation (no scatter, same trick as on_frac_hist); zero-weight
    rows contribute nothing, so padded hull rows are inert by
    construction. The keyword frame (min/bins-per-octave/bin count)
    defaults to the packet-delay histogram; the flow engine reuses the
    same machinery for its FCT and slowdown frames.
    """
    # the 1e-4 nudge keeps exact edge values in their own (half-open)
    # bin under f32 log2 rounding; it shifts edges by ~0.001%, far
    # below the ~12% bin resolution
    idx = jnp.clip(
        jnp.floor(jnp.log2(jnp.maximum(d, 1e-9) / min_val)
                  * bpo + 1e-4),
        -1, bins - 2).astype(jnp.int32) + 1
    onehot = jnp.arange(bins)[None, :] == idx[:, None]
    return hist + jnp.sum(w[:, None] * onehot, axis=0)


def on_frac_bucket(frac_on):
    """Quartile bucket of an on-fraction: (0,25], (25,50], (50,75],
    (75,100] — half-open-LEFT intervals, matching the on_frac_hist
    labels (an exact 25% boundary belongs to the lower bucket; 0 falls
    into the first)."""
    return jnp.clip(jnp.ceil(frac_on * 4.0).astype(jnp.int32) - 1, 0, 3)


class Scenario(NamedTuple):
    """Per-scenario knobs as array leaves (vmap axis 0 = scenario).

    Scalars per scenario; the batch builders stack them to (B,) arrays
    so the whole batch is one pytree the jitted step closes over. The
    last block is the scenario's REAL site shape inside the padded hull
    (equal to the hull for a single-site batch).
    """
    # traffic (TrafficSpec fields; p_spawn folds iat + rate_scale)
    p_spawn: jax.Array          # f32: P(new flow)/rack/tick while ON
    p_on_off: jax.Array         # f32
    p_off_on: jax.Array         # f32
    size_w: jax.Array           # f32 lognormal mixture weight
    size_mu1: jax.Array         # f32
    size_s1: jax.Array          # f32
    size_mu2: jax.Array         # f32
    size_s2: jax.Array          # f32
    p_intra_rack: jax.Array     # f32
    p_intra_cluster: jax.Array  # f32
    pace: jax.Array             # f32
    burst_pace_boost: jax.Array  # f32
    elephant_pkts: jax.Array    # int32
    elephant_pace: jax.Array    # f32
    # controller / datapath
    gating_enabled: jax.Array   # bool
    queue_cap: jax.Array        # f32
    hi: jax.Array               # f32
    lo: jax.Array               # f32
    dwell: jax.Array            # int32
    # optical fault model (all zero => bit-identical to the fault-free
    # path; sweepable with zero new compile sites)
    wake_fail_prob: jax.Array   # f32 P(stage-up firing fails)
    wake_jitter_frac: jax.Array  # f32 turn-on delay jitter (+- fraction)
    fault_prob: jax.Array       # f32 per-tick hard-fault hazard (1/MTBF)
    repair_ticks: jax.Array     # int32 hard-fault repair delay
    fault_fallback: jax.Array   # bool min-connectivity force-wake on/off
    plane_fail_prob: jax.Array  # f32 per-tick correlated whole-plane
    #                             hazard (one draw per laser comb)
    # flow-level workload engine (flow_mode=0 => the rate-based path
    # above, bit-identical; sweepable with zero new compile sites)
    flow_mode: jax.Array        # int32 0=rate-based, 1=flow engine
    flow_rate: jax.Array        # f32 P(arrival event)/rack/tick
    flow_dist: jax.Array        # int32 index into workloads.FLOW_DIST_NAMES
    incast: jax.Array           # int32 flows per arrival event (fan-in)
    flow_cap: jax.Array         # int32 usable flow-table slots (<= static)
    # site shape (real dims; <= the hull's static dims)
    ncl: jax.Array              # int32 n_clusters
    rpc: jax.Array              # int32 racks_per_cluster
    cpc: jax.Array              # int32 csw_per_cluster (= rsw uplinks)
    nfc: jax.Array              # int32 n_fc (= csw uplinks)
    spr: jax.Array              # f32 servers_per_rack
    csw_ring: jax.Array         # f32 cluster-ring pkts/tick budget
    fc_ring: jax.Array          # f32 FC-ring pkts/tick budget


class SimState(NamedTuple):
    key: jax.Array
    burst_on: jax.Array        # (R,) bool
    flow_rem: jax.Array        # (R, F) int32 remaining packets
    flow_dest: jax.Array       # (R, F) int32 0=rack 1=cluster 2=inter
    flow_fast: jax.Array       # (R, F) bool: line-rate elephant
    # flow engine (flow_mode=1): the fixed-capacity per-rack flow table
    # (FT = C.FLOW_TABLE_SLOTS static slots; a slot is live while
    # ft_rem > 0). All-zero and bit-inert at flow_mode=0.
    tick: jax.Array            # () int32 tick counter (arrival stamps)
    ft_start: jax.Array        # (R, FT) int32 arrival tick
    ft_rem: jax.Array          # (R, FT) f32 remaining packets
    ft_size: jax.Array        # (R, FT) int32 total flow size (pkts)
    ft_dst: jax.Array          # (R, FT) int32 0=rack 1=cluster 2=inter
    ft_cwnd: jax.Array         # (R, FT) f32 AIMD window (pkts/tick)
    rsw_q: jax.Array           # (R, P, 2) float [intra, inter]
    csw_up_q: jax.Array        # (NC, CUP) float
    csw_down_q: jax.Array      # (NC, RPC) float
    fc_down_q: jax.Array       # (NF, NC) float
    rsw_gate: gating.GateState
    csw_gate: gating.GateState
    rsw_fault: gating.FaultState   # per-uplink hard-fault carries
    csw_fault: gating.FaultState
    node_on: jax.Array         # (R,) float servers-links held on
    acc: dict                  # accumulators


#: SimParams fields forming the fault model's cache/meta fingerprint
FAULT_KNOBS = ("wake_fail_prob", "wake_jitter_frac", "link_mtbf_ticks",
               "repair_ticks", "fault_fallback", "plane_fail_prob")

#: SimParams fields forming the flow engine's cache/meta fingerprint
FLOW_KNOBS = ("flow_mode", "flow_arrival_rate", "flow_size_dist",
              "incast_degree", "flow_table_cap")


@dataclass(frozen=True)
class SimParams:
    spec: TrafficSpec
    site: FBSite = FBSite()
    gating_enabled: bool = True
    rate_scale: float = 1.0
    queue_cap: float = C.QUEUE_CAP_PKTS
    hi: float = C.HI_WATERMARK
    lo: float = C.LO_WATERMARK
    dwell: int = C.STAGE_DWELL_TICKS
    # optical fault model (defaults = the paper's perfect plane)
    wake_fail_prob: float = 0.0    # P(a stage-up firing fails), [0, 1)
    wake_jitter_frac: float = 0.0  # turn-on delay jitter fraction [0, 1]
    link_mtbf_ticks: float = 0.0   # mean ticks between hard faults per
    #                                powered link; 0 disables hard faults
    repair_ticks: int = 0          # hard-fault repair delay (>= 1 when
    #                                link_mtbf_ticks > 0)
    fault_fallback: bool = True    # min-connectivity force-wake
    plane_fail_prob: float = 0.0   # per-tick correlated whole-plane
    #                                hazard (shared laser comb dies ->
    #                                every link it feeds faults at
    #                                once); 0 disables plane faults
    # flow-level workload engine (default = the legacy rate-based path)
    flow_mode: int = 0             # 0=rate-based, 1=flow engine
    flow_arrival_rate: float = 0.0  # P(arrival event)/rack/tick; 0 =>
    #                                 derive from spec * rate_scale
    #                                 (traffic.flow_arrival_rate_per_tick)
    flow_size_dist: str = "websearch"  # workloads.FLOW_DIST_NAMES
    incast_degree: int = 1         # flows per arrival event (fan-in),
    #                                [1, C.MAX_INCAST_DEGREE]
    flow_table_cap: int = C.FLOW_TABLE_SLOTS  # usable slots per rack

    def __post_init__(self):
        """Reject out-of-range knobs with a clear error instead of
        silent NaN/garbage downstream (satellite of the fault PR)."""
        def bad(msg):
            raise ValueError(f"SimParams: {msg}")
        if not self.rate_scale >= 0.0:
            bad(f"rate_scale must be >= 0, got {self.rate_scale}")
        if not self.queue_cap > 0.0:
            bad(f"queue_cap must be > 0, got {self.queue_cap}")
        if not 0.0 < self.hi <= 1.0:
            bad(f"hi watermark must be in (0, 1], got {self.hi}")
        if not self.lo >= 0.0:
            bad(f"lo watermark must be >= 0, got {self.lo}")
        if self.lo >= self.hi:
            bad(f"inverted watermarks: lo ({self.lo}) >= hi ({self.hi})")
        if self.dwell < 0:
            bad(f"dwell must be >= 0, got {self.dwell}")
        if not 0.0 <= self.wake_fail_prob < 1.0:
            bad("wake_fail_prob must be in [0, 1), got "
                f"{self.wake_fail_prob}")
        if not 0.0 <= self.wake_jitter_frac <= 1.0:
            bad("wake_jitter_frac must be in [0, 1], got "
                f"{self.wake_jitter_frac}")
        if self.link_mtbf_ticks < 0.0:
            bad(f"link_mtbf_ticks must be >= 0 (0 disables hard "
                f"faults), got {self.link_mtbf_ticks}")
        if 0.0 < self.link_mtbf_ticks < 1.0:
            bad(f"link_mtbf_ticks must be >= 1 tick when nonzero, got "
                f"{self.link_mtbf_ticks}")
        if self.repair_ticks < 0:
            bad(f"repair_ticks must be >= 0, got {self.repair_ticks}")
        if self.link_mtbf_ticks > 0.0 and self.repair_ticks < 1:
            bad("repair_ticks must be >= 1 when hard faults are "
                f"enabled (link_mtbf_ticks={self.link_mtbf_ticks})")
        if not 0.0 <= self.plane_fail_prob < 1.0:
            bad("plane_fail_prob must be in [0, 1), got "
                f"{self.plane_fail_prob}")
        if self.plane_fail_prob > 0.0 and self.repair_ticks < 1:
            bad("repair_ticks must be >= 1 when plane faults are "
                f"enabled (plane_fail_prob={self.plane_fail_prob})")
        if self.flow_mode not in (0, 1):
            bad(f"flow_mode must be 0 (rate-based) or 1 (flow "
                f"engine), got {self.flow_mode}")
        if not 0.0 <= self.flow_arrival_rate <= 1.0:
            bad("flow_arrival_rate must be in [0, 1] (per-tick "
                f"Bernoulli; 0 derives from the trace), got "
                f"{self.flow_arrival_rate}")
        if self.flow_size_dist not in workloads.FLOW_DIST_NAMES:
            bad(f"flow_size_dist must be one of "
                f"{workloads.FLOW_DIST_NAMES}, got "
                f"{self.flow_size_dist!r}")
        if not 1 <= self.incast_degree <= C.MAX_INCAST_DEGREE:
            bad(f"incast_degree must be in [1, "
                f"{C.MAX_INCAST_DEGREE}] (the fixed draw width), got "
                f"{self.incast_degree}")
        if not 1 <= self.flow_table_cap <= C.FLOW_TABLE_SLOTS:
            bad(f"flow_table_cap must be in [1, "
                f"{C.FLOW_TABLE_SLOTS}] (the static table width), got "
                f"{self.flow_table_cap}")


def fault_fingerprint(p: "SimParams | None" = None) -> dict:
    """The fault-knob dict joined into result-cache keys / metadata
    (benchmarks/simcache.py) so fault-free cached results never alias
    faulted runs. With no argument, returns the defaults (the perfect
    optical plane)."""
    if p is None:
        import dataclasses
        return {f.name: f.default for f in dataclasses.fields(SimParams)
                if f.name in FAULT_KNOBS}
    return {k: getattr(p, k) for k in FAULT_KNOBS}


def flow_fingerprint(p: "SimParams | None" = None) -> dict:
    """The flow-knob dict joined into result-cache keys / metadata
    (benchmarks/simcache.py) so flow-free cached results never alias
    flow runs — the flow engine's ``fault_fingerprint`` analogue. With
    no argument, returns the defaults (the rate-based path)."""
    if p is None:
        import dataclasses
        return {f.name: f.default for f in dataclasses.fields(SimParams)
                if f.name in FLOW_KNOBS}
    return {k: getattr(p, k) for k in FLOW_KNOBS}


@dataclass(frozen=True)
class ScenarioBatch:
    """A stack of scenarios sharing one padded hull (one compile).

    ``hull`` is the static shape the step compiles against (the per-axis
    max over ``sites``); ``sites`` holds each scenario's real FBSite for
    metric normalization. For a single-site batch hull == sites[i].
    """
    scen: Scenario             # leaves shape (B,)
    hull: FBSite
    sites: tuple               # FBSite per scenario
    names: tuple               # trace name per scenario
    labels: tuple              # unique human label per scenario
    gating: tuple              # python bools (for metric finalization)
    seeds: tuple

    def __len__(self) -> int:
        return len(self.labels)


# hull/tag helpers live in topology.py now (the planner shares them);
# the old private names stay as aliases for existing callers
_pad_hull = pad_hull
_site_tag = site_tag


def _run_label(p: SimParams, seed: int, *, tag_site: bool) -> str:
    """THE scenario label format — shared by batch construction and the
    planned executor's structured error entries, so a failed bucket's
    placeholders carry the same label its metrics dict would have."""
    return (f"{p.spec.name}|{'lcdc' if p.gating_enabled else 'base'}"
            f"|x{p.rate_scale:g}|s{seed}"
            + (f"|{_site_tag(p.site)}" if tag_site else ""))


def _build_batch(runs: Sequence[tuple[SimParams, int]],
                 tag_sites: bool) -> ScenarioBatch:
    assert runs, "empty scenario batch"
    params = [p for p, _ in runs]
    sites = tuple(p.site for p in params)
    tf = stack_specs([p.spec for p in params])

    def f32(xs):
        return jnp.asarray(xs, jnp.float32)

    def i32(xs):
        return jnp.asarray(xs, jnp.int32)

    scen = Scenario(
        p_spawn=f32([min(rack_flow_rate_per_tick(p.spec,
                                                 p.site.servers_per_rack)
                         * p.rate_scale, 1.0) for p in params]),
        p_on_off=f32(tf["p_on_off"]), p_off_on=f32(tf["p_off_on"]),
        size_w=f32(tf["size_w"]),
        size_mu1=f32(tf["size_mu1"]), size_s1=f32(tf["size_s1"]),
        size_mu2=f32(tf["size_mu2"]), size_s2=f32(tf["size_s2"]),
        p_intra_rack=f32(tf["p_intra_rack"]),
        p_intra_cluster=f32(tf["p_intra_cluster"]),
        pace=f32(tf["pace"]),
        burst_pace_boost=f32(tf["burst_pace_boost"]),
        elephant_pkts=jnp.asarray(tf["elephant_pkts"], jnp.int32),
        elephant_pace=f32(tf["elephant_pace"]),
        gating_enabled=jnp.asarray([p.gating_enabled for p in params],
                                   bool),
        queue_cap=f32([p.queue_cap for p in params]),
        hi=f32([p.hi for p in params]), lo=f32([p.lo for p in params]),
        dwell=jnp.asarray([p.dwell for p in params], jnp.int32),
        wake_fail_prob=f32([p.wake_fail_prob for p in params]),
        wake_jitter_frac=f32([p.wake_jitter_frac for p in params]),
        # per-tick hazard: 1/MTBF (0 disables hard faults)
        fault_prob=f32([1.0 / p.link_mtbf_ticks
                        if p.link_mtbf_ticks > 0 else 0.0
                        for p in params]),
        repair_ticks=i32([p.repair_ticks for p in params]),
        fault_fallback=jnp.asarray([p.fault_fallback for p in params],
                                   bool),
        plane_fail_prob=f32([p.plane_fail_prob for p in params]),
        flow_mode=i32([p.flow_mode for p in params]),
        # explicit rate wins; 0 derives the legacy generator's expected
        # spawn rate so the two modes offer comparable load
        flow_rate=f32([p.flow_arrival_rate if p.flow_arrival_rate > 0.0
                       else flow_arrival_rate_per_tick(
                           p.spec, p.site.servers_per_rack,
                           p.rate_scale) for p in params]),
        flow_dist=i32([workloads.FLOW_DIST_NAMES.index(p.flow_size_dist)
                       for p in params]),
        incast=i32([p.incast_degree for p in params]),
        flow_cap=i32([p.flow_table_cap for p in params]),
        ncl=i32([p.site.n_clusters for p in params]),
        rpc=i32([p.site.racks_per_cluster for p in params]),
        cpc=i32([p.site.csw_per_cluster for p in params]),
        nfc=i32([p.site.n_fc for p in params]),
        spr=f32([p.site.servers_per_rack for p in params]),
        # 1 pkt/tick per 10G ring link
        csw_ring=f32([p.site.csw_ring_links for p in params]),
        fc_ring=f32([p.site.fc_ring_links for p in params]))
    labels = tuple(_run_label(p, seed, tag_site=tag_sites)
                   for p, seed in runs)
    return ScenarioBatch(
        scen=scen, hull=_pad_hull(sites), sites=sites,
        names=tuple(p.spec.name for p, _ in runs), labels=labels,
        gating=tuple(bool(p.gating_enabled) for p, _ in runs),
        seeds=tuple(int(s) for _, s in runs))


def make_batch(runs: Sequence[tuple[SimParams, int]]) -> ScenarioBatch:
    """Stack (SimParams, seed) pairs sharing ONE site into a batch."""
    assert runs, "empty scenario batch"
    site = runs[0][0].site
    assert all(p.site == site for p, _ in runs), \
        "make_batch takes one site topology; heterogeneous sites go " \
        "through make_multi_site_batch (padded hull, one compile)"
    return _build_batch(runs, tag_sites=False)


def make_multi_site_batch(
        runs: Sequence[tuple[SimParams, int]]) -> ScenarioBatch:
    """Stack (SimParams, seed) pairs on ARBITRARY FBSite variants into
    one batch that runs as ONE vmapped compile (the Fig 1
    design-comparison axis).

    Every scenario is padded to the batch hull (per-axis max) with
    validity masks; labels gain a ``|<ncl>x<rpc>c<cpc>f<nfc>`` site tag
    so same-spec runs on different sites stay distinguishable. Each
    scenario's metrics match its single-site ``run_sweep`` result
    (tests/test_topology_general.py pins this).
    """
    return _build_batch(runs, tag_sites=True)


def grid_runs(traces=None, gating=(True, False), seeds=(0,),
              rate_scales=(1.0,), site: FBSite = FBSite(),
              **params_kw) -> list:
    """(SimParams, seed) pairs for the standard scenario grid: traces x
    {LC/DC, always-on} x utilization (rate) scales x seeds — the
    Fig 9/10 evaluation matrix. The single definition of that grid,
    shared by sweep_grid and the serial/batched benchmark."""
    if traces is None:       # explicit () stays empty (make_batch rejects)
        traces = tuple(TRAFFIC_SPECS)
    return [(SimParams(spec=TRAFFIC_SPECS[t], site=site, gating_enabled=g,
                       rate_scale=rs, **params_kw), s)
            for t in traces
            for g in gating for rs in rate_scales for s in seeds]


def sweep_grid(traces=None, gating=(True, False), seeds=(0,),
               rate_scales=(1.0,), site: FBSite = FBSite(),
               **params_kw) -> ScenarioBatch:
    """The standard scenario grid as one vmappable batch."""
    return make_batch(grid_runs(traces, gating, seeds, rate_scales, site,
                                **params_kw))


def _site_masks(hull: FBSite, scen: Scenario):
    """Validity masks + logical rack ids of a real site inside the hull.

    Racks and CSWs occupy blocked cluster-major hull positions — rack r
    of cluster k sits at row k*hull.racks_per_cluster + r — so the
    step's reshapes to (n_clusters, ...) stay static while the REAL
    dims ride in as traced scenario knobs. Returns (rack_valid (R,),
    csw_valid (NC,), rack_uid (R,), rsw_max_stage (R,), csw_max_stage
    (NC,)); invalid switches get max stage 1 (they idle at the floor).
    """
    kk = jnp.arange(hull.n_clusters)
    rr = jnp.arange(hull.racks_per_cluster)
    cc = jnp.arange(hull.csw_per_cluster)
    cl_valid = kk < scen.ncl
    rack_valid = (cl_valid[:, None] & (rr[None, :] < scen.rpc)).reshape(-1)
    csw_valid = (cl_valid[:, None] & (cc[None, :] < scen.cpc)).reshape(-1)
    # logical id: position in the site's OWN (unpadded) rack order; the
    # PRNG is keyed on this, making traffic independent of hull padding
    rack_uid = (kk[:, None] * scen.rpc + rr[None, :]).reshape(-1)
    rsw_max = jnp.where(rack_valid, scen.cpc, 1).astype(jnp.int32)
    csw_max = jnp.where(csw_valid, scen.nfc, 1).astype(jnp.int32)
    return rack_valid, csw_valid, rack_uid, rsw_max, csw_max


def _init_state(hull: FBSite, scen: Scenario, key) -> SimState:
    s = hull
    R, P = s.n_racks, s.csw_per_cluster
    NC, RPC, NF = s.n_csw, s.racks_per_cluster, s.n_fc
    g = scen.gating_enabled
    rack_valid, csw_valid, _, rsw_max, csw_max = _site_masks(hull, scen)

    def tier_gate(n, links, pin):
        # gating on: stage floor 1; off: every REAL link up, pinned
        # there (padded links beyond the site's own never power on)
        base = gating.gate_init(n, links)
        stage = jnp.where(g, base.stage, pin)
        powered = jnp.where(g, base.powered,
                            jnp.arange(links)[None, :] < pin[:, None])
        return base._replace(stage=stage, powered=powered)

    acc = {
        "rsw_backlog": jnp.zeros(()), "rsw_served": jnp.zeros(()),
        "csw_up_backlog": jnp.zeros(()), "csw_up_served": jnp.zeros(()),
        "csw_down_backlog": jnp.zeros(()), "csw_down_served": jnp.zeros(()),
        "fc_backlog": jnp.zeros(()), "fc_served": jnp.zeros(()),
        "ring_pkts": jnp.zeros(()), "fc_ring_pkts": jnp.zeros(()),
        "injected": jnp.zeros(()), "intra_rack": jnp.zeros(()),
        "drops": jnp.zeros(()),
        "rsw_powered": jnp.zeros(()), "csw_powered": jnp.zeros(()),
        "node_on": jnp.zeros(()),
        "half_off_ticks": jnp.zeros(()),
        "on_frac_hist": jnp.zeros((4,)),   # (0-25,25-50,50-75,75-100]% on
        # in-scan packet-delay distribution (log-spaced bins, see module
        # docstring) + the attribution split feeding _finalize
        "delay_hist": jnp.zeros((C.DELAY_HIST_BINS,)),
        "delay_sum": jnp.zeros(()),        # sum w * d (us-packets)
        "delay_wt": jnp.zeros(()),         # total sampled packets
        "delay_wt_inter": jnp.zeros(()),   # inter-cluster sampled packets
        "delay_queue_sum": jnp.zeros(()),  # queue-wait part of delay_sum
        "delay_stall_sum": jnp.zeros(()),  # wake-stall part of delay_sum
        "wake_stall_pkts": jnp.zeros(()),  # packets arriving mid stage-up
        # optical fault model (all exactly 0 with zero fault knobs)
        "fault_drops": jnp.zeros(()),      # pkts lost to dying links
        "delay_fault_sum": jnp.zeros(()),  # fault_stall part of delay_sum
        "fault_stall_pkts": jnp.zeros(()),  # pkts arriving mid force-wake
        "wake_retries": jnp.zeros(()),     # failed stage-up firings
        "forced_wakes": jnp.zeros(()),     # min-connectivity fallbacks
        "fault_link_ticks": jnp.zeros(()),  # hard-faulted link-ticks
        "conn_loss_rack_ticks": jnp.zeros(()),   # valid RSWs with a
        "conn_loss_csw_ticks": jnp.zeros(()),    # healthy-but-unusable
        #                                          uplink set (ticks)
        # post-serve occupancy moments from the switch kernel
        "rsw_occ_m1": jnp.zeros(()), "rsw_occ_m2": jnp.zeros(()),
        "csw_occ_m1": jnp.zeros(()), "csw_occ_m2": jnp.zeros(()),
        # flow engine (all exactly 0 at flow_mode=0: no flow is ever
        # admitted, every add below is masked to +0.0)
        "flows_started": jnp.zeros(()),    # includes evicted arrivals
        "flows_completed": jnp.zeros(()),
        "flows_evicted": jnp.zeros(()),    # table-overflow rejections
        "fct_sum": jnp.zeros(()),          # sum FCT (us) over completions
        "fct_slow_sum": jnp.zeros(()),     # sum FCT/ideal slowdown
        # per-size-class (short/medium/long) completion histograms:
        # FCT in the FCT_BIN_EDGES_US frame, slowdown in the
        # FCT_SLOWDOWN_BIN_EDGES frame
        "fct_hist": jnp.zeros((3, C.FCT_HIST_BINS)),
        "fct_slow_hist": jnp.zeros((3, C.FCT_SLOWDOWN_HIST_BINS)),
    }
    return SimState(
        key=key,
        burst_on=jnp.ones((R,), bool),
        flow_rem=jnp.zeros((R, F_SLOTS), jnp.int32),
        flow_dest=jnp.zeros((R, F_SLOTS), jnp.int32),
        flow_fast=jnp.zeros((R, F_SLOTS), bool),
        tick=jnp.zeros((), jnp.int32),
        ft_start=jnp.zeros((R, C.FLOW_TABLE_SLOTS), jnp.int32),
        ft_rem=jnp.zeros((R, C.FLOW_TABLE_SLOTS), jnp.float32),
        ft_size=jnp.zeros((R, C.FLOW_TABLE_SLOTS), jnp.int32),
        ft_dst=jnp.zeros((R, C.FLOW_TABLE_SLOTS), jnp.int32),
        ft_cwnd=jnp.zeros((R, C.FLOW_TABLE_SLOTS), jnp.float32),
        rsw_q=jnp.zeros((R, P, 2)),
        csw_up_q=jnp.zeros((NC, s.csw_uplinks)),
        csw_down_q=jnp.zeros((NC, RPC)),
        fc_down_q=jnp.zeros((NF, NC)),
        rsw_gate=tier_gate(R, P, rsw_max),
        csw_gate=tier_gate(NC, s.csw_uplinks, csw_max),
        rsw_fault=gating.fault_init(R, P),
        csw_fault=gating.fault_init(NC, s.csw_uplinks),
        node_on=jnp.zeros((R,)),
        acc=acc,
    )


def _spawn_flows(scen: Scenario, k_u, k_z, rack_uid, rack_valid,
                 burst_on, flow_rem, flow_dest, flow_fast):
    """Per-rack flow arrivals: Bernoulli spawn into the first free slot.

    All per-rack randomness is keyed by fold_in(tick key, rack_uid) —
    the rack's LOGICAL id within its own site, not its row in the
    padded hull — so a site's traffic is bit-identical whether it runs
    at exact dims or padded inside a heterogeneous multi-site batch.
    Returns the updated flow state plus this tick's per-flow pace
    uniforms (R, F_SLOTS).
    """
    ku = jax.vmap(lambda i: jax.random.fold_in(k_u, i))(rack_uid)
    kz = jax.vmap(lambda i: jax.random.fold_in(k_z, i))(rack_uid)
    u = jax.vmap(lambda k: jax.random.uniform(k, (5 + F_SLOTS,)))(ku)
    z = jax.vmap(lambda k: jax.random.normal(k, (2,)))(kz)

    # ON/OFF burst Markov
    stay_on = u[:, 0] > scen.p_on_off
    wake = u[:, 1] < scen.p_off_on
    burst_on = jnp.where(burst_on, stay_on, wake)

    # padded hull rows never spawn: they stay empty forever; with the
    # flow engine selected (flow_mode=1) the legacy table never fills
    # (the mask is a scalar True at flow_mode=0, so the rate-based
    # path's draws and spawns are bit-untouched)
    spawn = (u[:, 2] < scen.p_spawn) & burst_on & rack_valid \
        & (scen.flow_mode == 0)

    # lognormal mixture sizes -> packets (1250 B per packet)
    pick_mix = u[:, 3] < scen.size_w
    size_b = jnp.where(pick_mix,
                       jnp.exp(scen.size_mu1 + scen.size_s1 * z[:, 0]),
                       jnp.exp(scen.size_mu2 + scen.size_s2 * z[:, 1]))
    size_p = jnp.maximum(jnp.ceil(size_b / 1250.0), 1.0).astype(jnp.int32)

    ud = u[:, 4]
    dest = jnp.where(ud < scen.p_intra_rack, 0,
                     jnp.where(ud < scen.p_intra_rack + scen.p_intra_cluster,
                               1, 2)).astype(jnp.int32)

    free = flow_rem == 0
    first_free = jnp.argmax(free, axis=1)               # (R,)
    has_free = jnp.any(free, axis=1)
    do = spawn & has_free
    # dense one-hot slot update instead of a scatter: vmapped scatters
    # are slow on CPU XLA and this keeps the sweep engine's batched
    # per-tick cost near the serial path's
    slot = do[:, None] & (jnp.arange(F_SLOTS)[None, :]
                          == first_free[:, None])       # (R,F)
    flow_rem = flow_rem + jnp.where(slot, size_p[:, None], 0)
    flow_dest = jnp.where(slot, dest[:, None], flow_dest)
    fast = size_p >= scen.elephant_pkts
    flow_fast = jnp.where(slot, fast[:, None], flow_fast)
    return burst_on, flow_rem, flow_dest, flow_fast, u[:, 5:]


def make_sim_step(hull: FBSite):
    """One tick for ONE scenario on the static padded ``hull``; every
    scenario knob — including the scenario's real site dims — is a
    traced scalar, so jax.vmap(step) batches arbitrarily many scenarios
    (on heterogeneous sites fitting the hull) per compile."""
    s = hull
    NCL, RPC = s.n_clusters, s.racks_per_cluster
    P = s.csw_per_cluster     # plane axis: RSW uplink c IS cluster-CSW c
    NF = s.n_fc
    CUP = s.csw_uplinks       # == NF (FBSite invariant: uplink f -> FC f)
    R, NC = s.n_racks, s.n_csw
    assert P <= MAX_FAULT_LINKS and CUP <= MAX_FAULT_LINKS, \
        f"hull link axes ({P}, {CUP}) exceed the fixed fault-draw " \
        f"width MAX_FAULT_LINKS={MAX_FAULT_LINKS}"

    def step(scen: Scenario, state: SimState) -> SimState:
        acc = dict(state.acc)
        rack_valid, csw_valid, rack_uid, rsw_max, csw_max = \
            _site_masks(hull, scen)
        rpcf = scen.rpc.astype(jnp.float32)
        nclf = scen.ncl.astype(jnp.float32)
        key, k_u, k_z = jax.random.split(state.key, 3)

        # fault-model randomness: dedicated fold_in branches of the tick
        # key (constants far above any logical switch id) so the
        # existing traffic streams are bit-untouched, then one
        # FIXED-width uniform block per switch keyed by its LOGICAL id —
        # identical draws whether a site runs at exact dims or padded
        # inside a heterogeneous hull. Layout: [0]=wake jitter,
        # [1]=wake-failure, [2+l]=hard-fault hazard of link l.
        k_fr = jax.random.fold_in(k_u, 0x7F000001)
        k_fc = jax.random.fold_in(k_u, 0x7F000002)
        csw_uid = ((jnp.arange(NC) // P) * scen.cpc
                   + jnp.arange(NC) % P).astype(jnp.int32)

        def fault_draws(base, uids):
            ks = jax.vmap(lambda i: jax.random.fold_in(base, i))(uids)
            return jax.vmap(
                lambda k: jax.random.uniform(k, (2 + MAX_FAULT_LINKS,))
            )(ks)

        u_fr = fault_draws(k_fr, rack_uid)                  # (R, 2+16)
        u_fc = fault_draws(k_fc, csw_uid)                   # (NC, 2+16)

        # correlated failure domains (plane_fail_prob): ONE hazard draw
        # per shared laser comb, broadcast to every link it feeds, so a
        # comb death takes the whole plane down in one tick. RSW tier:
        # plane p of cluster k is fed by cluster-CSW (k, p) — all of
        # cluster k's rack uplinks p share one draw. CSW tier: FC f's
        # comb feeds csw uplink f site-wide — one draw per FC. New
        # dedicated fold_in branches + the fixed MAX_FAULT_LINKS draw
        # width keep every existing stream bit-untouched and the draws
        # padding-invariant (cluster/plane ids are logical hull
        # positions; real dims are prefix slices of the fixed block).
        k_pr = jax.random.fold_in(k_u, 0x7F000005)
        k_pc = jax.random.fold_in(k_u, 0x7F000006)
        u_plane_cl = jax.vmap(
            lambda k: jax.random.uniform(k, (MAX_FAULT_LINKS,)))(
            jax.vmap(lambda i: jax.random.fold_in(k_pr, i))(
                jnp.arange(NCL, dtype=jnp.int32)))          # (NCL, 16)
        u_plane_r = jnp.broadcast_to(
            u_plane_cl[:, None, :P], (NCL, RPC, P)).reshape(R, P)
        u_plane_c = jnp.broadcast_to(
            jax.random.uniform(k_pc, (MAX_FAULT_LINKS,))[None, :CUP],
            (NC, CUP))

        rsw_ok = state.rsw_fault.timer == 0                 # (R, P)
        csw_ok = state.csw_fault.timer == 0                 # (NC, CUP)
        link_idx_p = jnp.arange(P)[None, :]
        link_idx_c = jnp.arange(CUP)[None, :]
        rsw_link_real = rack_valid[:, None] & (link_idx_p
                                               < rsw_max[:, None])
        csw_link_real = csw_valid[:, None] & (link_idx_c
                                              < csw_max[:, None])

        # 1. traffic edge ------------------------------------------------
        burst_on, flow_rem, flow_dest, flow_fast, pace_u = _spawn_flows(
            scen, k_u, k_z, rack_uid, rack_valid, state.burst_on,
            state.flow_rem, state.flow_dest, state.flow_fast)
        active = flow_rem > 0                                   # (R,F)
        # paced emission: mice trickle below line rate (boosted during
        # bursts); elephants transmit at line rate -- overlapping
        # elephants are what push queues over the high watermark.
        pace_eff = jnp.minimum(
            scen.pace * jnp.where(burst_on, scen.burst_pace_boost, 1.0),
            1.0)[:, None]
        pace_flow = jnp.where(flow_fast, scen.elephant_pace, pace_eff)
        emit = active & (pace_u < pace_flow)
        n_holding = jnp.sum(active, axis=1).astype(jnp.float32)  # (R,)
        by_dest = jnp.stack(
            [jnp.sum(emit & (flow_dest == d), axis=1) for d in (0, 1, 2)],
            axis=1).astype(jnp.float32)                          # (R,3)
        flow_rem = jnp.maximum(flow_rem - emit.astype(jnp.int32), 0)

        # 1b. flow-level workload engine (flow_mode=1): fixed-capacity
        # per-rack flow table, pFabric-style heavy-tailed sizes
        # (core/workloads.py), AIMD cwnd — all array ops selected
        # against the rate-based path above by jnp.where, so both modes
        # share ONE compiled program and flow_mode=0 stays bit-identical
        # to the pre-flow engine (the fault-knob discipline: dedicated
        # fold_in branches, fixed draw widths, masked accumulator adds).
        flow_on = scen.flow_mode > 0
        tick_now = state.tick + 1
        FT = C.FLOW_TABLE_SLOTS
        k_fa = jax.random.fold_in(k_u, 0x7F000003)   # arrival + dst
        k_fs = jax.random.fold_in(k_u, 0x7F000004)   # flow sizes
        ka = jax.vmap(lambda i: jax.random.fold_in(k_fa, i))(rack_uid)
        ua = jax.vmap(lambda k: jax.random.uniform(k, (2,)))(ka)
        ks = jax.vmap(lambda i: jax.random.fold_in(k_fs, i))(rack_uid)
        us = jax.vmap(lambda k: jax.random.uniform(
            k, (C.MAX_INCAST_DEGREE,)))(ks)          # fixed draw width
        # one arrival EVENT spawns `incast` flows converging on the
        # same destination class (the fan-in pattern that stresses the
        # table and the watermark controller together)
        arrive = (ua[:, 0] < scen.flow_rate) & rack_valid & flow_on
        n_new = jnp.where(arrive, scen.incast, 0)            # (R,)
        sizes = workloads.sample_flow_size_pkts(
            us, scen.flow_dist)                              # (R,W) f32
        ud2 = ua[:, 1]
        fdst = jnp.where(
            ud2 < scen.p_intra_rack, 0,
            jnp.where(ud2 < scen.p_intra_rack + scen.p_intra_cluster,
                      1, 2)).astype(jnp.int32)               # (R,)
        # admission: rank the usable free slots (traced flow_cap caps
        # the static FT axis) and match candidate k to the k-th free
        # slot — a whole incast burst admits in one tick, overflow is
        # EVICTION (counted; started == completed + evicted + in-flight
        # stays exact)
        slot_i = jnp.arange(FT)[None, :]
        usable_slot = slot_i < scen.flow_cap                 # (1,FT)
        pre_live = (state.ft_rem > 0.0) & usable_slot        # (R,FT)
        free = ~pre_live & usable_slot
        rank = jnp.cumsum(free.astype(jnp.int32), axis=1) - 1
        cand = jnp.arange(C.MAX_INCAST_DEGREE)
        want = cand[None, :] < n_new[:, None]                # (R,W)
        place = (free[:, :, None]
                 & (rank[:, :, None] == cand[None, None, :])
                 & want[:, None, :])                         # (R,FT,W)
        admitted = jnp.any(place, axis=1)                    # (R,W)
        placed = jnp.any(place, axis=2)                      # (R,FT)
        new_sz = jnp.sum(jnp.where(place, sizes[:, None, :], 0.0),
                         axis=2)                             # (R,FT)
        # AIMD on the PREVIOUS tick's live flows: halve on the rack's
        # hi-watermark congestion signal (previous tick's RSW queues —
        # the 1-tick feedback delay of a real rack-local signal),
        # additive increase toward line rate otherwise
        cong, _ = gating.watermark_triggers(
            jnp.sum(state.rsw_q, axis=2), state.rsw_gate.stage,
            cap=scen.queue_cap, hi=scen.hi, lo=scen.lo)
        ft_cwnd = jnp.where(
            pre_live,
            jnp.where(cong[:, None],
                      jnp.maximum(state.ft_cwnd * C.FLOW_AIMD_DECREASE,
                                  C.FLOW_CWND_MIN_PPT),
                      jnp.minimum(state.ft_cwnd
                                  + C.FLOW_AIMD_INCREASE_PPT,
                                  C.FLOW_LINE_RATE_PPT)),
            state.ft_cwnd)
        ft_start = jnp.where(placed, tick_now, state.ft_start)
        ft_rem = jnp.where(placed, new_sz, state.ft_rem)
        ft_size = jnp.where(placed, new_sz.astype(jnp.int32),
                            state.ft_size)
        ft_dst = jnp.where(placed, fdst[:, None], state.ft_dst)
        ft_cwnd = jnp.where(placed, C.FLOW_CWND_INIT_PPT, ft_cwnd)
        # emission: every live flow sends min(rem, cwnd) this tick
        # (fluid, like the aggregation tiers); the last fraction
        # completes the flow
        ft_live = (ft_rem > 0.0) & usable_slot
        emit_f = jnp.where(ft_live, jnp.minimum(ft_rem, ft_cwnd), 0.0)
        ft_rem = ft_rem - emit_f
        done = ft_live & (ft_rem <= 0.0)                     # (R,FT)
        flow_by_dest = jnp.stack(
            [jnp.sum(jnp.where(ft_dst == d, emit_f, 0.0), axis=1)
             for d in (0, 1, 2)], axis=1)                    # (R,3)
        # select the traffic edge the datapath sees; at flow_mode=0
        # every flow accumulator add below is exactly +0.0
        by_dest = jnp.where(flow_on, flow_by_dest, by_dest)
        n_holding = jnp.where(
            flow_on, jnp.sum(ft_live, axis=1).astype(jnp.float32),
            n_holding)
        acc["flows_started"] += jnp.sum(n_new).astype(jnp.float32)
        acc["flows_evicted"] += (jnp.sum(n_new)
                                 - jnp.sum(admitted)).astype(jnp.float32)

        acc["injected"] += jnp.sum(by_dest[:, 1:])
        acc["intra_rack"] += jnp.sum(by_dest[:, 0])

        # 2+3. RSW datapath tick: min-backlog enqueue of the [intra,
        # inter] arrival split + 1 pkt/tick serve per active uplink —
        # the shared switch-step kernel (Pallas on TPU, ref on CPU).
        # The valid mask is per-LINK: hull padding AND hard-faulted
        # transceivers (a dead port neither accepts nor serves).
        (rsw_q, served_split, _, _, rsw_drop, rsw_wait, rsw_m1,
         rsw_m2) = ops.switch_step(
            state.rsw_q, state.rsw_gate.stage, by_dest[:, 1:],
            state.rsw_gate.draining, valid=rack_valid[:, None] & rsw_ok,
            cap=scen.queue_cap, hi=scen.hi, lo=scen.lo, serve_rate=1.0)
        acc["drops"] += jnp.sum(rsw_drop)
        acc["rsw_backlog"] += jnp.sum(rsw_q) + jnp.sum(served_split)
        acc["rsw_served"] += jnp.sum(served_split)
        acc["rsw_occ_m1"] += jnp.sum(rsw_m1)
        acc["rsw_occ_m2"] += jnp.sum(rsw_m2)

        # uplink c of rack r lands on CSW (cluster(r), c): the uplink
        # axis IS the csw_per_cluster plane axis (FBSite invariant)
        srv_rc = served_split.reshape(NCL, RPC, P, 2)
        to_csw = jnp.sum(srv_rc, axis=1)                         # (NCL,P,2)
        inter_in = to_csw[..., 1].reshape(NC)

        # Stage-aware down-plane weights (the per-stage CAM tables of
        # Sec III-B): traffic for rack r rides plane c with weight
        # active(r,c)/stage(r); dest racks are uniform within the
        # cluster. Padded hull rows carry zero weight.
        rsw_stage_f = state.rsw_gate.stage.astype(jnp.float32)
        plane_w = (jnp.arange(P)[None, :] < state.rsw_gate.stage[:, None]) \
            / rsw_stage_f[:, None] * rack_valid[:, None]         # (R,P)
        plane_w_c = plane_w.reshape(NCL, RPC, P)

        # 4. CSW: intra-cluster traffic -> down queues. A packet for rack
        # r arriving UP at csw c may have to cross to plane c' active for
        # r; within a cluster that crossing is the CSW ring. We charge the
        # ring for the mismatch between arrival plane and dest plane.
        intra_cl = jnp.sum(to_csw[..., 0], axis=1)               # (NCL,)
        dest_share = intra_cl[:, None, None] / rpcf * \
            plane_w_c.transpose(0, 2, 1)                         # (NCL,P,RPC)
        csw_down_q = state.csw_down_q + dest_share.reshape(NC, RPC)
        # ring charge: fraction of intra traffic whose up-plane != down-plane
        up_share = to_csw[..., 0] / jnp.maximum(intra_cl[:, None], 1e-9)
        # per-plane mean dest weight over the cluster's REAL racks
        mean_down = jnp.sum(plane_w_c, axis=1) / rpcf            # (NCL,P)
        same_plane = jnp.sum(jnp.minimum(up_share, mean_down), axis=1)
        acc["ring_pkts"] += jnp.sum(intra_cl * (1.0 - same_plane))

        # 5. CSW uplink datapath tick (40G: 4 pkt/tick) -> FC, through
        # the same shared switch-step kernel (single component).
        (csw_up_q, cserve, _, _, csw_drop, csw_wait, csw_m1,
         csw_m2) = ops.switch_step(
            state.csw_up_q, state.csw_gate.stage, inter_in,
            state.csw_gate.draining, valid=csw_valid[:, None] & csw_ok,
            cap=scen.queue_cap, hi=scen.hi, lo=scen.lo, serve_rate=4.0)
        acc["drops"] += jnp.sum(csw_drop)
        acc["csw_up_backlog"] += jnp.sum(state.csw_up_q)
        acc["csw_up_served"] += jnp.sum(cserve)
        acc["csw_occ_m1"] += jnp.sum(csw_m1)
        acc["csw_occ_m2"] += jnp.sum(csw_m2)

        # uplink f of csw c lands on FC f (the csw_uplinks axis; == n_fc
        # by the FBSite invariant). The FC routes traffic for cluster k
        # down an ACTIVE (f, c') plane of that cluster (per-stage CAMs):
        # weight by the cluster's csw-uplink activity and by the dest
        # rack's active planes.
        fc_in = jnp.sum(cserve, axis=0)                          # (CUP,)
        csw_stage_f = state.csw_gate.stage.astype(jnp.float32)
        fc_w = (jnp.arange(CUP)[None, :]
                < state.csw_gate.stage[:, None]) / csw_stage_f[:, None]
        # csw c's share of its cluster's down traffic = how much of the
        # cluster's REAL racks ride plane (c mod csw_per_cluster)
        csw_share = (jnp.sum(plane_w_c, axis=1) / rpcf).reshape(NC)
        # total inter-cluster down traffic splits uniformly over the
        # REAL clusters
        down_cl = jnp.sum(fc_in) / nclf                          # scalar
        fc_down_add = down_cl * csw_share[None, :] * fc_w.T      # (NF,NC)
        fc_down_q = state.fc_down_q + fc_down_add

        # 6. FC down serve: link (f,c) active iff csw stage[c] > f AND
        #    csw c's uplink-f transceiver is healthy (it is the same
        #    fiber); any residual on an inactive/dead plane rides the
        #    FC ring to the always-on f=0 plane.
        fc_active = (jnp.arange(NF)[:, None]
                     < state.csw_gate.stage[None, :]) & csw_ok.T  # (NF,NC)
        fserve = jnp.minimum(fc_down_q, 4.0) * fc_active
        fc_down_q = fc_down_q - fserve
        stranded = jnp.where(~fc_active, fc_down_q, 0.0)
        mig = jnp.minimum(jnp.sum(stranded), scen.fc_ring)
        mfrac = mig / jnp.maximum(jnp.sum(stranded), 1e-9)
        fc_down_q = fc_down_q - stranded * mfrac
        fc_down_q = fc_down_q.at[0, :].add(
            jnp.sum(stranded * mfrac, axis=0))
        acc["fc_ring_pkts"] += mig
        acc["fc_backlog"] += jnp.sum(state.fc_down_q)
        acc["fc_served"] += jnp.sum(fserve)

        # FC-served packets land on csw c -> its down queues, weighted by
        # each rack's active planes (stage-aware, as above)
        per_csw_down = jnp.sum(fserve, axis=0)                   # (NC,)
        pw_cr = plane_w_c.transpose(0, 2, 1).reshape(NC, RPC)    # (NC,RPC)
        row_w = jnp.sum(pw_cr, axis=1)                           # (NC,)
        pw_norm = pw_cr / jnp.maximum(row_w[:, None], 1e-9)
        routable = row_w > 0.0
        csw_down_q = csw_down_q + \
            jnp.where(routable, per_csw_down, 0.0)[:, None] * pw_norm
        # a csw can still drain FC backlog for a plane no rack currently
        # rides (every rack staged below it after the queue built up);
        # that traffic rides the cluster ring to the always-on plane 0
        # rather than vanishing (conservation: injected == delivered +
        # in-flight + drops)
        orphan = jnp.where(routable, 0.0, per_csw_down)          # (NC,)
        orphan_cl = jnp.sum(orphan.reshape(NCL, P), axis=1)      # (NCL,)
        dest0 = pw_norm.reshape(NCL, P, RPC)[:, 0, :]            # (NCL,RPC)
        csw_down_q = (csw_down_q.reshape(NCL, P, RPC)
                      .at[:, 0, :].add(orphan_cl[:, None] * dest0)
                      .reshape(NC, RPC))
        acc["ring_pkts"] += jnp.sum(orphan_cl)

        # 7. CSW down serve: link (r, c) active iff rsw stage[r] > c AND
        #    rack r's uplink-c transceiver is healthy (same fiber) —
        #    the plane axis is csw_per_cluster; stranded traffic rides
        #    the cluster ring to c=0.
        rsw_stage = state.rsw_gate.stage.reshape(NCL, RPC)
        rsw_ok_pl = rsw_ok.reshape(NCL, RPC, P) \
            .transpose(0, 2, 1)                                  # (NCL,P,RPC)
        cidx = jnp.arange(P)[None, :, None]                      # plane pos
        down_act = (cidx < rsw_stage[:, None, :]) & rsw_ok_pl    # (NCL,P,RPC)
        dq = csw_down_q.reshape(NCL, P, RPC)
        dserve = jnp.minimum(dq, 1.0) * down_act
        dq = dq - dserve
        stranded_d = jnp.where(~down_act, dq, 0.0)               # (NCL,P,RPC)
        tot_str = jnp.sum(stranded_d, axis=(1, 2))               # (NCL,)
        migd = jnp.minimum(tot_str, scen.csw_ring)
        dfrac = (migd / jnp.maximum(tot_str, 1e-9))[:, None, None]
        moved = stranded_d * dfrac
        dq = dq - moved
        dq = dq.at[:, 0, :].add(jnp.sum(moved, axis=1))
        csw_down_q = dq.reshape(NC, RPC)
        acc["ring_pkts"] += jnp.sum(migd)
        acc["csw_down_backlog"] += jnp.sum(state.csw_down_q)
        delivered_r = jnp.sum(dserve, axis=1).reshape(R)         # (R,)
        acc["csw_down_served"] += jnp.sum(dserve)

        # 8. node-level link gating (OS intercept: zero latency cost).
        # A server link is held on while its server has active flows (tx)
        # or receives traffic, with an idle timeout.
        need = jnp.minimum(n_holding + delivered_r, scen.spr)
        node_on = jnp.maximum(
            need, state.node_on - scen.spr / NODE_IDLE_TICKS)
        acc["node_on"] += jnp.sum(node_on)

        # 8.5 in-scan delay sampling (see module docstring): one sample
        # per rack per destination class for the packets injected THIS
        # tick, fed by the kernel's backlog-age taps plus the
        # gating-attributed wake stall. (R, planes) view of the CSW down
        # queues each rack faces — shared with the step-9 RSW trigger.
        down_rc = csw_down_q.reshape(NCL, P, RPC) \
            .transpose(0, 2, 1).reshape(R, P)                # (R, planes)
        # queue waits: RSW enqueue (kernel), CSW down plane-weighted
        # (1 pkt/tick links), CSW uplink arrival-weighted per cluster,
        # FC capacity-normalized (4 pkt/tick per active real link)
        down_wait = jnp.sum(plane_w * down_rc, axis=1)           # (R,)
        win = inter_in.reshape(NCL, P)

        def cl_avg(x):
            # arrival-weighted per-cluster mean over the cluster's CSWs
            return jnp.sum(win * x.reshape(NCL, P), axis=1) \
                / jnp.maximum(jnp.sum(win, axis=1), 1e-9)        # (NCL,)

        w_csw_cl = cl_avg(csw_wait)
        fc_cap = 4.0 * jnp.sum((fc_active & csw_valid[None, :])
                               .astype(jnp.float32))
        fc_wait = jnp.sum(fc_down_q) / jnp.maximum(fc_cap, 1e-9)
        # wake stalls: remaining STAGE_UP_DELAY ticks of an in-flight
        # stage-up at the switches this rack's packets traverse; exactly
        # zero with gating disabled (up_timer never leaves 0, and the
        # attribution is masked besides)
        # wake + fault-forced stalls through the ONE attribution seam
        # (gating.stall_attribution): the same pair feeds the delay
        # histogram below AND the flow FCT samples, so gating stalls
        # attribute into flow completion times by construction; both
        # are EXACTLY 0 when gating is off
        g_on = scen.gating_enabled
        stall_rsw, fstall_rsw = gating.stall_attribution(
            state.rsw_gate, state.rsw_fault, g_on)               # (R,)
        stall_csw, fstall_csw = gating.stall_attribution(
            state.csw_gate, state.csw_fault, g_on)               # (NC,)
        stall_csw_cl = cl_avg(stall_csw)
        fstall_csw_cl = cl_avg(fstall_csw)

        def per_rack(x_cl):                                      # (NCL,)->(R,)
            return jnp.broadcast_to(x_cl[:, None], (NCL, RPC)).reshape(R)

        wt_i, wt_x = by_dest[:, 1], by_dest[:, 2]      # intra-cl / inter
        q_i = rsw_wait + down_wait                     # queue-wait parts
        q_x = q_i + per_rack(w_csw_cl) + fc_wait
        s_i = stall_rsw                                # wake-stall parts
        s_x = stall_rsw + per_rack(stall_csw_cl)
        f_i = fstall_rsw                               # fault-stall parts
        f_x = fstall_rsw + per_rack(fstall_csw_cl)
        base_i = STACK_US + 4.0 * WIRE_HOP_US
        d_i = base_i + q_i + s_i + f_i
        d_x = base_i + 2.0 * WIRE_HOP_US + q_x + s_x + f_x
        hist = _delay_hist_add(acc["delay_hist"], d_i, wt_i)
        acc["delay_hist"] = _delay_hist_add(hist, d_x, wt_x)
        acc["delay_sum"] += jnp.sum(wt_i * d_i) + jnp.sum(wt_x * d_x)
        acc["delay_wt"] += jnp.sum(wt_i) + jnp.sum(wt_x)
        acc["delay_wt_inter"] += jnp.sum(wt_x)
        acc["delay_queue_sum"] += jnp.sum(wt_i * q_i) + jnp.sum(wt_x * q_x)
        acc["delay_stall_sum"] += jnp.sum(wt_i * s_i) + jnp.sum(wt_x * s_x)
        acc["delay_fault_sum"] += jnp.sum(wt_i * f_i) + jnp.sum(wt_x * f_x)
        acc["wake_stall_pkts"] += jnp.sum(wt_i * (s_i > 0)) \
            + jnp.sum(wt_x * (s_x > 0))
        acc["fault_stall_pkts"] += jnp.sum(wt_i * (f_i > 0)) \
            + jnp.sum(wt_x * (f_x > 0))

        # 8.6 flow completion times (flow_mode=1; every weight below is
        # exactly 0 at flow_mode=0). FCT = table residence + THIS
        # tick's sampled path delay for the flow's class — d_i/d_x
        # already carry queue waits plus the wake/fault stalls, so
        # gating stalls attribute into FCT through the same seam as the
        # delay histogram. Slowdown is vs the ideal-bandwidth baseline
        # (line-rate serialization + unloaded path); residence >= size
        # (per-tick emission <= line rate) and path >= the unloaded
        # path, so slowdown >= 1 by construction.
        wdone = done.astype(jnp.float32)                     # (R,FT)
        residence = (tick_now - ft_start + 1).astype(jnp.float32)
        path_us = jnp.where(
            ft_dst == 2, d_x[:, None],
            jnp.where(ft_dst == 1, d_i[:, None], STACK_US))
        fct_us = residence * C.TICK_US + path_us
        ideal_base = jnp.where(
            ft_dst == 2, base_i + 2.0 * WIRE_HOP_US,
            jnp.where(ft_dst == 1, base_i, STACK_US))
        ideal_us = workloads.ideal_fct_us(ft_size, ideal_base)
        slow = fct_us / ideal_us
        cls = workloads.flow_size_class(ft_size)             # (R,FT)
        fct_flat = fct_us.reshape(-1)
        slow_flat = slow.reshape(-1)
        for c in range(3):
            wc = (wdone * (cls == c)).reshape(-1)
            acc["fct_hist"] = acc["fct_hist"].at[c].set(
                _delay_hist_add(
                    acc["fct_hist"][c], fct_flat, wc,
                    min_val=C.FCT_HIST_MIN_US,
                    bpo=C.FCT_HIST_BINS_PER_OCTAVE,
                    bins=C.FCT_HIST_BINS))
            acc["fct_slow_hist"] = acc["fct_slow_hist"].at[c].set(
                _delay_hist_add(
                    acc["fct_slow_hist"][c], slow_flat, wc,
                    min_val=C.FCT_SLOWDOWN_HIST_MIN,
                    bpo=C.FCT_SLOWDOWN_HIST_BINS_PER_OCTAVE,
                    bins=C.FCT_SLOWDOWN_HIST_BINS))
        acc["flows_completed"] += jnp.sum(wdone)
        acc["fct_sum"] += jnp.sum(fct_us * wdone)
        acc["fct_slow_sum"] += jnp.sum(slow * wdone)

        # 9. watermark controllers. Per Sec III-B the backlog monitor
        # watches ALL output queues of a switch: the RSW trigger combines
        # its uplink queues with the CSW down-queue pressure on each
        # plane-to-rack link, and the CSW trigger combines its FC uplink
        # queues with the FC down-queue pressure per plane (a saturated
        # 40G down plane must open the next stage). gating_enabled is a
        # traced scenario knob: the controller always steps and the
        # result is selected, so LC/DC and always-on scenarios share one
        # compiled program. max_stage caps each switch at its REAL link
        # count (padded hull links never activate).
        #
        # 9a. hard-fault evolution FIRST (applies to LC/DC and always-on
        # scenarios alike: transceivers die regardless of the
        # controller): Bernoulli arrivals on powered healthy real links,
        # repair countdown, and the dying link's queued packets move to
        # the fault-drop conservation bin (a dead laser transmits
        # nothing; injected == delivered + in-flight + drops +
        # fault_drops stays exact).
        rsw_timer, rsw_new_f = gating.fault_arrivals(
            state.rsw_fault.timer, u_fr[:, 2:2 + P],
            state.rsw_gate.powered, rsw_link_real,
            scen.fault_prob, scen.repair_ticks,
            plane_u=u_plane_r, plane_fail_prob=scen.plane_fail_prob)
        csw_timer, csw_new_f = gating.fault_arrivals(
            state.csw_fault.timer, u_fc[:, 2:2 + CUP],
            state.csw_gate.powered, csw_link_real,
            scen.fault_prob, scen.repair_ticks,
            plane_u=u_plane_c, plane_fail_prob=scen.plane_fail_prob)
        acc["fault_drops"] += \
            jnp.sum(jnp.where(rsw_new_f[..., None], rsw_q, 0.0)) \
            + jnp.sum(jnp.where(csw_new_f, csw_up_q, 0.0))
        rsw_q = jnp.where(rsw_new_f[..., None], 0.0, rsw_q)
        csw_up_q = jnp.where(csw_new_f, 0.0, csw_up_q)
        acc["fault_link_ticks"] += jnp.sum(rsw_timer > 0) \
            + jnp.sum(csw_timer > 0)

        # 9b. the controllers, fault-aware: jittered/failing wakes plus
        # the min-connectivity fallback (force-wake the cheapest healthy
        # link when the usable prefix died; stall charged to the
        # fault_stall carry). All knobs zero => bit-identical GateState.
        rsw_gated, rsw_fwake, rsw_diag = gating.gate_step(
            state.rsw_gate, jnp.maximum(jnp.sum(rsw_q, axis=2), down_rc),
            cap=scen.queue_cap, hi=scen.hi, lo=scen.lo, dwell=scen.dwell,
            max_stage=rsw_max, link_ok=rsw_timer == 0,
            link_real=rsw_link_real, u_jitter=u_fr[:, 0],
            u_fail=u_fr[:, 1], wake_fail_prob=scen.wake_fail_prob,
            wake_jitter_frac=scen.wake_jitter_frac,
            fault_wake=state.rsw_fault.wake,
            fallback=scen.fault_fallback)
        csw_gated, csw_fwake, csw_diag = gating.gate_step(
            state.csw_gate, jnp.maximum(csw_up_q, fc_down_q.T),
            cap=scen.queue_cap, hi=scen.hi, lo=scen.lo, dwell=scen.dwell,
            max_stage=csw_max, link_ok=csw_timer == 0,
            link_real=csw_link_real, u_jitter=u_fc[:, 0],
            u_fail=u_fc[:, 1], wake_fail_prob=scen.wake_fail_prob,
            wake_jitter_frac=scen.wake_jitter_frac,
            fault_wake=state.csw_fault.wake,
            fallback=scen.fault_fallback)

        def sel(new, old):
            return jax.tree.map(
                lambda a, b: jnp.where(scen.gating_enabled, a, b), new, old)

        rsw_gate = sel(rsw_gated, state.rsw_gate)
        csw_gate = sel(csw_gated, state.csw_gate)
        # the fallback (and its stall) only exists under gating: an
        # always-on scenario's links are already all up, so the carry is
        # pinned to 0 — fault_stall attribution exactly 0, as pinned by
        # the acceptance tests
        rsw_fwake = jnp.where(g_on, rsw_fwake, 0)
        csw_fwake = jnp.where(g_on, csw_fwake, 0)
        acc["wake_retries"] += jnp.where(
            g_on, jnp.sum(rsw_diag["retries"]) +
            jnp.sum(csw_diag["retries"]), 0)
        acc["forced_wakes"] += jnp.where(
            g_on, jnp.sum(rsw_diag["forced"]) +
            jnp.sum(csw_diag["forced"]), 0)

        # 9c. min-connectivity audit on the END-of-tick state: a valid
        # switch that still HAS a healthy real link but zero usable
        # ones records a connectivity-loss tick — loss attributable to
        # the gating policy (links powered off), which the fallback
        # must drive to exactly 0. A switch whose real links are ALL
        # simultaneously hard-faulted is excluded: no routing/gating
        # policy can preserve its connectivity, and that hardware
        # unavailability is already visible in link_fault_frac /
        # delivered_frac.
        rsw_healthy = (rsw_timer == 0) & rsw_link_real
        csw_healthy = (csw_timer == 0) & csw_link_real
        rsw_usable_f = gating.usable_links(rsw_gate.stage,
                                           rsw_gate.draining, P) \
            & rsw_healthy
        csw_usable_f = gating.usable_links(csw_gate.stage,
                                           csw_gate.draining, CUP) \
            & csw_healthy
        acc["conn_loss_rack_ticks"] += jnp.sum(
            rack_valid & jnp.any(rsw_healthy, axis=1)
            & ~jnp.any(rsw_usable_f, axis=1))
        acc["conn_loss_csw_ticks"] += jnp.sum(
            csw_valid & jnp.any(csw_healthy, axis=1)
            & ~jnp.any(csw_usable_f, axis=1))

        # power accounting: a hard-faulted transceiver is dark — it
        # draws nothing while dead, whatever the controller thinks
        rsw_pow = jnp.sum(
            jnp.where(rack_valid[:, None] & (rsw_timer == 0),
                      rsw_gate.powered, False))
        csw_pow = jnp.sum(
            jnp.where(csw_valid[:, None] & (csw_timer == 0),
                      csw_gate.powered, False))
        acc["rsw_powered"] += rsw_pow
        acc["csw_powered"] += csw_pow
        # gated-link population of the REAL site:
        # ncl*rpc*cpc (RSW-CSW) + ncl*cpc*nfc (CSW-FC)
        cpcf = scen.cpc.astype(jnp.float32)
        nfcf = scen.nfc.astype(jnp.float32)
        n_gated = nclf * cpcf * (rpcf + nfcf)
        frac_on = (rsw_pow + csw_pow) / n_gated
        acc["half_off_ticks"] += (frac_on <= 0.5)
        # half-open-LEFT quartiles (0,25],(25,50],(50,75],(75,100]: an
        # exact boundary (e.g. the all-floor 25% state) belongs to the
        # LOWER bucket, matching the histogram labels
        bucket = on_frac_bucket(frac_on)
        acc["on_frac_hist"] += (jnp.arange(4) == bucket)  # one-hot, no scatter

        return SimState(key, burst_on, flow_rem, flow_dest, flow_fast,
                        tick_now, ft_start, ft_rem, ft_size, ft_dst,
                        ft_cwnd,
                        rsw_q, csw_up_q, csw_down_q, fc_down_q,
                        rsw_gate, csw_gate,
                        gating.FaultState(rsw_timer, rsw_fwake),
                        gating.FaultState(csw_timer, csw_fwake),
                        node_on, acc)

    return step


class SweepValidationError(RuntimeError):
    """Raised by ``validate=True`` sweeps when the in-program guards
    (finite-value / conservation, see ``run_sweep``) tripped. Carries
    ``labels`` (the failing scenarios) and ``first_bad_chunk`` (the
    earliest chunk index at which any of them first failed)."""

    def __init__(self, labels, first_bad_chunk):
        self.labels = tuple(labels)
        self.first_bad_chunk = int(first_bad_chunk)
        super().__init__(
            f"sweep validation failed for scenario(s) {list(labels)} "
            f"(first failing chunk: {first_bad_chunk})")


#: test hook for the fault-tolerant planned executor: when set, called
#: as ``BUCKET_FAIL_HOOK(bucket_index, phase)`` with phase in
#: {"dispatch", "fetch", "retry"} before the corresponding stage of
#: each bucket; raising from it simulates a bucket failure
#: (tests/test_faults.py uses this to pin the isolation contract)
BUCKET_FAIL_HOOK = None

#: preemption-injection seam for the durable executor: when set, called
#: as ``CHUNK_HOOK(chunk_index)`` at the top of every chunk-loop
#: iteration (before that chunk is dispatched); raising from it
#: simulates a crash/preemption at an exact chunk boundary
#: (tests/test_durability.py kills runs here and resumes them)
CHUNK_HOOK = None

#: monkeypatchable sleep used by the retry-backoff loop, so tests can
#: pin the exact backoff sequence without waiting wall-clock time
RETRY_SLEEP = time.sleep


@dataclass(frozen=True)
class BucketRetryPolicy:
    """Retry/deadline policy for ``run_sweep_planned`` bucket failures.

    The default reproduces the PR 6 contract exactly: ONE serial retry
    on the conservative ``fold="host"`` path, immediately, with no
    deadline. ``backoff_s(r)`` is the sleep before retry attempt ``r``
    (1-based): ``min(backoff_base_s * backoff_mult**(r-1),
    backoff_max_s)``, or 0 when ``backoff_base_s`` is 0 (no sleep).
    ``deadline_s`` bounds each bucket's cumulative wall-clock time
    across its attempts: once exceeded, remaining retries are abandoned
    and the bucket degrades to a structured error entry. The deadline
    never discards finished work — a bucket that completed (however
    slowly) keeps its results; only further RETRIES are cut off.
    """
    max_retries: int = 1
    backoff_base_s: float = 0.0
    backoff_mult: float = 2.0
    backoff_max_s: float = 60.0
    deadline_s: float | None = None

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"BucketRetryPolicy: {msg}")
        if self.max_retries < 0:
            bad(f"max_retries must be >= 0, got {self.max_retries}")
        if self.backoff_base_s < 0.0:
            bad(f"backoff_base_s must be >= 0, got {self.backoff_base_s}")
        if self.backoff_mult < 1.0:
            bad(f"backoff_mult must be >= 1, got {self.backoff_mult}")
        if self.backoff_max_s < 0.0:
            bad(f"backoff_max_s must be >= 0, got {self.backoff_max_s}")
        if self.deadline_s is not None and self.deadline_s < 0.0:
            bad(f"deadline_s must be >= 0, got {self.deadline_s}")

    def backoff_s(self, attempt: int) -> float:
        """Sleep (seconds) before 1-based retry ``attempt``."""
        if self.backoff_base_s <= 0.0:
            return 0.0
        return min(self.backoff_base_s * self.backoff_mult ** (attempt - 1),
                   self.backoff_max_s)


def _fold_dtype():
    """The device fold-buffer dtype: float64 where the backend enables
    x64, otherwise float32 (compensated with a Kahan pair)."""
    return jax.dtypes.canonicalize_dtype(np.float64)


def _should_shard(n_scenarios: int, shard: bool | None) -> bool:
    """THE sharding-eligibility predicate, shared by ``_start_sweep``
    (actual execution) and ``execution_mode`` (cache keys / records) so
    the two can never drift: shard when more than one local device is
    visible and the batch has more than one scenario (a single
    scenario has nothing to distribute)."""
    n_dev = jax.local_device_count()
    want = shard if shard is not None else n_dev > 1
    return bool(want and n_dev > 1 and n_scenarios > 1)


def execution_mode(*, fold: str = "device", shard: bool | None = None,
                   n_scenarios: int | None = None):
    """The execution-layer knobs that can shift float results — joined
    into result-cache keys (benchmarks/simcache.py) so runs under a
    different fold path, fold precision or device layout never serve
    each other stale results. Pass ``n_scenarios`` (the batch size)
    when known: it applies the same ``_should_shard`` predicate
    ``_start_sweep`` uses, so the reported layout matches the actual
    execution."""
    sharded = _should_shard(2 if n_scenarios is None else n_scenarios,
                            shard)
    return {"fold": fold,
            "fold_dtype": jnp.dtype(_fold_dtype()).name,
            "devices": jax.local_device_count() if sharded else 1}


def _sweep_chunk_impl(site: FBSite, scen: Scenario, state: SimState,
                      length: int, live, fold, guard=None, chunk_idx=None,
                      tol=None, validate: bool = False):
    global TRACE_COUNT
    TRACE_COUNT += 1          # python side effect: counts traces only
    if TRACE_HOOK is not None:
        TRACE_HOOK(site)      # trace-time attribution (sanitizer seam)
    step = make_sim_step(site)
    vstep = jax.vmap(step)

    def tick(st, is_live):
        # a dead (masked remainder) tick passes the carry through
        # unchanged, so the tail chunk reuses this same trace; is_live
        # is a scalar (not vmapped), so the cond genuinely branches —
        # dead ticks skip the step instead of computing-and-discarding
        return jax.lax.cond(is_live, lambda s: vstep(scen, s),
                            lambda s: s, st), None

    out, _ = jax.lax.scan(tick, state, live, length=length)
    new_fold = None
    if fold is not None:
        # device-resident fold: absorb this chunk's accumulators into
        # the (sum, comp) Kahan buffer and re-zero them, all inside this
        # same program — the chunk loop never synchronizes with the host
        fsum, fcomp = fold
        nsum, ncomp = {}, {}
        for k in out.acc:
            v = out.acc[k].astype(fsum[k].dtype)
            y = v - fcomp[k]
            t = fsum[k] + y
            nsum[k] = t
            ncomp[k] = (t - fsum[k]) - y
        out = out._replace(acc=jax.tree.map(jnp.zeros_like, out.acc))
        new_fold = (nsum, ncomp)
    if not validate:
        return out, new_fold, guard
    # ---- opt-in in-program guards (validate=True) -----------------------
    # per-scenario finite-value check over the in-flight queues and the
    # running totals, plus the conservation identity
    #   injected == delivered + drops + fault_drops + in-flight
    # on the device-fold path (the totals live on device there). The
    # guard carries, per scenario, the first chunk index at which any
    # check failed (-1 = clean); chunk_idx/tol are traced scalars so
    # the chunk loop still reuses one executable.
    B = guard.shape[0]

    def finite(arrs):
        ok = jnp.ones((B,), bool)
        for a in arrs:
            ok &= jnp.all(jnp.isfinite(a.reshape(B, -1)), axis=1)
        return ok

    queues = (out.rsw_q, out.csw_up_q, out.csw_down_q, out.fc_down_q)
    ok = finite(queues)
    if new_fold is not None:
        tot = {k: new_fold[0][k] - new_fold[1][k] for k in new_fold[0]}
        ok &= finite(tuple(tot.values()))
        in_flight = sum(jnp.sum(q.reshape(B, -1), axis=1) for q in queues)
        inj = tot["injected"]
        resid = inj - (tot["csw_down_served"] + tot["drops"]
                       + tot["fault_drops"] + in_flight.astype(inj.dtype))
        ok &= jnp.abs(resid) <= tol * jnp.maximum(inj, 1.0)
        # flow-conservation identity (exactly 0 residual at
        # flow_mode=0, where every term is 0): started == completed +
        # evicted + in-table; in-table counts the live usable slots of
        # the end-of-chunk flow table
        in_table = jnp.sum(
            (out.ft_rem > 0.0)
            & (jnp.arange(C.FLOW_TABLE_SLOTS)[None, None, :]
               < scen.flow_cap[:, None, None]), axis=(1, 2))
        started = tot["flows_started"]
        fresid = started - (tot["flows_completed"] + tot["flows_evicted"]
                            + in_table.astype(started.dtype))
        ok &= jnp.abs(fresid) <= tol * jnp.maximum(started, 1.0)
    else:
        # host-fold path: the running totals are host-side; guard the
        # chunk's own accumulators for finiteness only
        ok &= finite(tuple(out.acc.values()))
    guard = jnp.where((guard < 0) & ~ok, chunk_idx, guard)
    return out, new_fold, guard


@functools.lru_cache(maxsize=None)
def _sweep_runner():
    # carry donation is a no-op (warning) on CPU; enable it only where
    # the backend supports buffer donation
    kw = {} if jax.default_backend() == "cpu" \
        else {"donate_argnames": ("state", "fold")}
    return jax.jit(_sweep_chunk_impl,
                   static_argnames=("site", "length", "validate"), **kw)


@functools.lru_cache(maxsize=None)
def _scen_sharding():
    """One ``NamedSharding`` over the scenario batch axis for all local
    devices (cached: pjit executable reuse keys on sharding equality)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    mesh = Mesh(np.array(jax.devices()), ("scen",))
    return NamedSharding(mesh, PartitionSpec("scen"))


@dataclass
class _PendingSweep:
    """A dispatched-but-not-fetched sweep: every chunk program is
    enqueued on device; the only host synchronization left is the fold
    fetch in ``_finish_sweep`` (one transfer)."""
    batch: ScenarioBatch
    n_ticks: int
    fold: tuple | None       # device (sum, comp) trees (fold="device")
    acc64: dict | None       # host float64 accumulators (fold="host")
    state: SimState          # final device state (maybe padded/sharded)
    n_real: int              # batch rows before devices-multiple padding
    guard: object = None     # (B,) int32 first-bad-chunk (validate=True)


def _prepare_sweep_args(batch: ScenarioBatch, *, fold: str = "device",
                        shard: bool | None = None, validate: bool = False,
                        validate_tol: float | None = None):
    """Build the chunk-program operands for a batch: hull-shaped
    per-scenario state, the device (sum, comp) Kahan fold buffer and
    the optional validate guard — padded to a devices multiple and
    placed on the scenario-axis sharding when the sharded path is
    eligible.

    Shared seam: ``_start_sweep`` dispatches exactly these operands
    through ``_sweep_runner()``, and the artifact auditor
    (repro.analysis.artifact) AOT-lowers the runner on exactly these
    operands — so the audited HLO is the HLO the sweep engine runs, not
    a re-derived lookalike. Returns ``(scen, state, dev_fold, guard,
    tol)``.
    """
    hull = batch.hull
    n_real = len(batch)
    scen = batch.scen
    # one fused key build for the whole batch (vectorized; the old code
    # was an O(batch) host loop of per-seed jax.random.PRNGKey device
    # calls), matching PRNGKey's own canonicalization in BOTH x64
    # modes: with x64 the seed is an int64 and the key keeps the high
    # word; without it any Python int truncates to its low 32 bits
    # (-1 -> 4294967295, 2**32+5 -> 5; a bare uint32 cast would raise)
    if jax.dtypes.canonicalize_dtype(np.int64) == jnp.int64:
        seeds = jnp.asarray(batch.seeds, jnp.int64)
    else:
        seeds = jnp.asarray([s & 0xFFFFFFFF for s in batch.seeds],
                            jnp.uint32)

    sharding = None
    if _should_shard(n_real, shard):
        n_dev = jax.local_device_count()
        sharding = _scen_sharding()
        # pad the batch to a devices-multiple with copies of scenario 0:
        # scenarios are independent vmap lanes, so pad rows are bit-inert
        # for every real row and simply dropped before finalization
        pad = (-n_real) % n_dev
        if pad:
            def _pad0(x):
                return jnp.concatenate(
                    [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
            scen = jax.tree.map(_pad0, scen)
            seeds = _pad0(seeds)

    keys = jax.vmap(jax.random.PRNGKey)(seeds)
    state = jax.vmap(lambda sc, k: _init_state(hull, sc, k))(scen, keys)

    dev_fold = None
    if fold == "device":
        zeros = jax.tree.map(lambda a: jnp.zeros(a.shape, _fold_dtype()),
                             state.acc)
        dev_fold = (zeros, jax.tree.map(jnp.zeros_like, zeros))
    guard = tol = None
    if validate:
        guard = jnp.full((int(seeds.shape[0]),), -1, jnp.int32)
        tol = jnp.asarray(C.VALIDATE_CONS_REL_TOL if validate_tol is None
                          else validate_tol, jnp.float32)
    if sharding is not None:
        scen = jax.device_put(scen, sharding)
        state = jax.device_put(state, sharding)
        if dev_fold is not None:
            dev_fold = jax.device_put(dev_fold, sharding)
        if guard is not None:
            guard = jax.device_put(guard, sharding)
    return scen, state, dev_fold, guard, tol


def _dispatch_chunks(batch: ScenarioBatch, scen: Scenario, state: SimState,
                     dev_fold, guard, tol, *, n_ticks: int, chunk: int,
                     fold: str, validate: bool, n_real: int,
                     start_chunk: int = 0,
                     checkpoint: "CheckpointSpec | None" = None,
                     plan_meta: dict | None = None) -> _PendingSweep:
    """THE chunk loop, shared by ``_start_sweep`` (fresh runs, from
    chunk 0) and ``resume_sweep`` (restored runs, from the checkpoint's
    chunk index) so a resumed run replays byte-for-byte the same
    dispatch sequence a fresh run would have executed from that
    boundary. ``chunk`` is the EFFECTIVE chunk length
    (``max(1, min(chunk_ticks, n_ticks))``) — a checkpoint records it
    and resume reuses it, so the live-tick masks line up exactly.

    Checkpointing (``checkpoint`` set; device fold only) snapshots the
    full carry at every ``every_chunks`` boundary, DEFERRED BY ONE
    CHUNK: the snapshot taken at boundary ``ci`` is written only after
    chunk ``ci`` (the next one) has been dispatched, so the device
    always has work enqueued while the host fetches and serializes —
    cadenced snapshots throttle but never serialize the async pipeline.
    The final boundary is never snapshotted (the run is finished, not
    resumable, there).
    """
    global HOST_TRANSFER_COUNT
    runner = _sweep_runner()
    hull = batch.hull
    acc64 = None
    done = start_chunk * chunk
    ci = start_chunk
    pending_snap = None
    while done < n_ticks:
        if CHUNK_HOOK is not None:
            CHUNK_HOOK(ci)
        live = jnp.arange(chunk) < (n_ticks - done)
        state, dev_fold, guard = runner(
            hull, scen, state, chunk, live, dev_fold, guard,
            jnp.asarray(ci, jnp.int32), tol, validate)
        ci += 1
        if fold == "host":
            # legacy path: fold this chunk's accumulators into float64
            # on the host and zero them on device — one blocking
            # transfer per chunk
            chunk_acc = jax.device_get(state.acc)
            HOST_TRANSFER_COUNT += 1
            if acc64 is None:
                acc64 = {k: np.zeros(np.shape(v), np.float64)
                         for k, v in chunk_acc.items()}
            for k, v in chunk_acc.items():
                acc64[k] += np.asarray(v, np.float64)
            state = state._replace(
                acc=jax.tree.map(jnp.zeros_like, state.acc))
        done += chunk
        if pending_snap is not None:
            _snapshot_sweep(checkpoint, batch, *pending_snap,
                            n_ticks=n_ticks, chunk=chunk,
                            validate=validate, tol=tol, n_real=n_real,
                            plan_meta=plan_meta)
            pending_snap = None
        if (checkpoint is not None and done < n_ticks
                and ci % checkpoint.every_chunks == 0):
            pending_snap = (ci, state, dev_fold, guard)
    return _PendingSweep(batch=batch, n_ticks=n_ticks, fold=dev_fold,
                         acc64=acc64, state=state, n_real=n_real,
                         guard=guard)


def _start_sweep(batch: ScenarioBatch, n_ticks: int, *,
                 chunk_ticks: int = CHUNK_TICKS, fold: str = "device",
                 shard: bool | None = None, validate: bool = False,
                 validate_tol: float | None = None,
                 checkpoint: "CheckpointSpec | None" = None,
                 plan_meta: dict | None = None) -> _PendingSweep:
    """Dispatch a sweep's chunk programs without fetching results.

    With ``fold="device"`` (default) this returns as soon as the last
    chunk is ENQUEUED — jax dispatch is asynchronous, so the caller can
    trace/compile the next bucket while this one executes. The legacy
    ``fold="host"`` path synchronizes at every chunk boundary (the
    pre-PR-5 behaviour, kept for parity pinning).

    ``checkpoint`` (a :class:`CheckpointSpec`) snapshots the full
    per-scenario carry at the spec's chunk cadence — device fold only:
    the snapshot IS the device fold buffer plus the SimState carry, and
    the host path already synchronizes per chunk, so checkpointing it
    would pin a second fetch discipline for no benefit.
    """
    if fold not in ("device", "host"):
        raise ValueError(f"fold must be 'device' or 'host', got {fold!r}")
    if n_ticks < 1:
        raise ValueError(f"n_ticks must be >= 1, got {n_ticks}")
    if checkpoint is not None and fold != "device":
        raise ValueError(
            "checkpointing requires the device-resident fold "
            f"(fold='device'); got fold={fold!r}")
    n_real = len(batch)
    scen, state, dev_fold, guard, tol = _prepare_sweep_args(
        batch, fold=fold, shard=shard, validate=validate,
        validate_tol=validate_tol)
    return _dispatch_chunks(
        batch, scen, state, dev_fold, guard, tol, n_ticks=n_ticks,
        chunk=max(1, min(chunk_ticks, n_ticks)), fold=fold,
        validate=validate, n_real=n_real, checkpoint=checkpoint,
        plan_meta=plan_meta)


def _finish_sweep(p: _PendingSweep, return_state: bool = False):
    """Fetch a dispatched sweep's fold buffer (the run's single host
    transfer on the device-fold path) and finalize per-scenario
    metrics. A ``validate=True`` sweep whose in-program guards tripped
    raises ``SweepValidationError`` here (the guard rides the same
    transfer as the fold, so the one-transfer contract holds)."""
    global HOST_TRANSFER_COUNT
    guard_h = None
    if p.fold is not None:
        if p.guard is not None:
            (fsum, fcomp), guard_h = jax.device_get((p.fold, p.guard))
        else:
            fsum, fcomp = jax.device_get(p.fold)
        HOST_TRANSFER_COUNT += 1
        # Kahan: sum carries the running total, comp the rounding error
        # still to subtract; apply the residual in float64 on the host
        acc64 = {k: np.asarray(fsum[k], np.float64)
                 - np.asarray(fcomp[k], np.float64) for k in fsum}
    else:
        acc64 = p.acc64
        if p.guard is not None:
            guard_h = jax.device_get(p.guard)
            HOST_TRANSFER_COUNT += 1
    batch = p.batch
    if guard_h is not None:
        bad = [i for i in range(p.n_real) if int(guard_h[i]) >= 0]
        if bad:
            raise SweepValidationError(
                [batch.labels[i] for i in bad],
                min(int(guard_h[i]) for i in bad))
    res = [
        _finalize({k: v[i] for k, v in acc64.items()}, batch.sites[i],
                  p.n_ticks, batch.gating[i], batch.names[i],
                  batch.labels[i])
        for i in range(len(batch))
    ]
    if return_state:
        state = jax.device_get(p.state)
        # drop devices-multiple pad rows (copies of scenario 0)
        state = jax.tree.map(lambda x: x[:p.n_real], state)
        return res, state
    return res


def _snapshot_sweep(spec: CheckpointSpec, batch: ScenarioBatch,
                    ci: int, state: SimState, dev_fold, guard, *,
                    n_ticks: int, chunk: int, validate: bool, tol,
                    n_real: int, plan_meta: dict | None = None):
    """Write one checkpoint of a running sweep's full carry.

    THE registered checkpoint fetch (an RL003 blessed transfer): ONE
    explicit ``jax.device_get`` of the whole carry — every SimState
    leaf, the device Kahan fold ``(sum, comp)`` buffers, the validate
    guard, and the scenario batch — per cadence boundary, counted by
    ``HOST_TRANSFER_COUNT`` (so a checkpointed run's pin is exactly
    ``1 + n_checkpoints``). Devices-multiple pad rows (copies of
    scenario 0, bit-inert) are stripped before writing; resume re-pads
    for whatever device layout it finds, which is exact because a pad
    row is a FULL copy of row 0 (same scenario, same seed, same carry)
    and scenarios are independent vmap lanes.
    """
    global HOST_TRANSFER_COUNT
    scen_h, state_h, fold_h, guard_h = jax.device_get(
        (batch.scen, state, dev_fold, guard))
    HOST_TRANSFER_COUNT += 1
    state_h = jax.tree.map(lambda x: np.asarray(x)[:n_real], state_h)
    arrays = {}
    for name, leaf in zip(Scenario._fields, scen_h):
        arrays[f"scen/{name}"] = np.asarray(leaf)
    for path, leaf in jax.tree_util.tree_flatten_with_path(state_h)[0]:
        arrays["state" + jax.tree_util.keystr(path)] = np.asarray(leaf)
    fsum, fcomp = fold_h
    for k, v in fsum.items():
        arrays[f"fold_sum/{k}"] = np.asarray(v)[:n_real]
    for k, v in fcomp.items():
        arrays[f"fold_comp/{k}"] = np.asarray(v)[:n_real]
    if guard_h is not None:
        arrays["guard"] = np.asarray(guard_h)[:n_real]
    meta = {
        "sim_schema": SIM_SCHEMA_VERSION,
        "fault_knobs": list(FAULT_KNOBS),
        "flow_knobs": list(FLOW_KNOBS),
        "scenario_fields": list(Scenario._fields),
        # the fold dtype pins the JAX_ENABLE_X64 mode: float64 iff x64
        "fold_dtype": jnp.dtype(_fold_dtype()).name,
        "n_ticks": int(n_ticks), "chunk_ticks": int(chunk),
        "chunk_index": int(ci), "n_real": int(n_real),
        "validate": bool(validate),
        "validate_tol": float(tol) if tol is not None else None,
        "hull": dataclasses.asdict(batch.hull),
        "sites": [dataclasses.asdict(s) for s in batch.sites],
        "names": list(batch.names), "labels": list(batch.labels),
        "gating": [bool(g) for g in batch.gating],
        "seeds": [int(s) for s in batch.seeds],
        "plan": plan_meta, "tag": spec.tag,
    }
    path = _ckpt.write_checkpoint(spec.path_for(ci), meta, arrays)
    _ckpt.prune(spec)
    return path


def resume_sweep(path, *, return_state: bool = False,
                 shard: bool | None = None,
                 checkpoint: "CheckpointSpec | None" = None):
    """Restart an interrupted sweep from a checkpoint file and run it
    to completion — BIT-identically to the uninterrupted run.

    The checkpoint carries the full per-scenario carry at a chunk
    boundary plus the run geometry, so the remaining chunks replay
    exactly the dispatch sequence the original run would have executed
    (same effective chunk length, same live-tick masks, same per-tick
    ``fold_in`` PRNG streams — nothing about the randomness depends on
    wall-clock history). Works across device layouts: the saved rows
    are re-padded/re-sharded for THIS process's devices (pad rows are
    bit-inert copies of row 0), so a run checkpointed on one device may
    resume on four, and vice versa. The x64 mode, however, must match:
    every restored dtype (fold buffers above all) pins it, and a
    mismatch fails fast.

    Raises :class:`CheckpointError` (reason naming the first mismatch:
    "format"/"checksum" from the file layer, "sim_schema",
    "fingerprint", "scenario_fields", "x64_mode", "state_schema" from
    the engine-compatibility checks) rather than resuming from a
    checkpoint this engine cannot reproduce. Pass ``checkpoint`` (a
    :class:`CheckpointSpec`) to KEEP checkpointing the resumed run at
    the same absolute chunk cadence.
    """
    meta, arrays = _ckpt.read_checkpoint(path)

    def reject(reason, detail):
        raise CheckpointError(reason, f"{path}: {detail}")

    if meta.get("sim_schema") != SIM_SCHEMA_VERSION:
        reject("sim_schema",
               f"written at SIM_SCHEMA_VERSION={meta.get('sim_schema')!r}"
               f", this engine is {SIM_SCHEMA_VERSION}")
    if meta.get("fault_knobs") != list(FAULT_KNOBS) \
            or meta.get("flow_knobs") != list(FLOW_KNOBS):
        reject("fingerprint",
               f"fault/flow knob inventory {meta.get('fault_knobs')!r}/"
               f"{meta.get('flow_knobs')!r} != this engine's "
               f"{list(FAULT_KNOBS)!r}/{list(FLOW_KNOBS)!r}")
    if meta.get("scenario_fields") != list(Scenario._fields):
        reject("scenario_fields",
               f"scenario leaves {meta.get('scenario_fields')!r} != "
               f"this engine's {list(Scenario._fields)!r}")
    fold_dtype = jnp.dtype(_fold_dtype()).name
    if meta.get("fold_dtype") != fold_dtype:
        reject("x64_mode",
               f"written with fold dtype {meta.get('fold_dtype')!r} "
               f"(JAX_ENABLE_X64={meta.get('fold_dtype') == 'float64'}),"
               f" this process folds in {fold_dtype!r}")
    missing_scen = [f for f in Scenario._fields
                    if f"scen/{f}" not in arrays]
    if missing_scen:
        reject("scenario_fields",
               f"scenario leaf arrays missing: {missing_scen}")

    hull = FBSite(**meta["hull"])
    scen = Scenario(**{f: jnp.asarray(arrays[f"scen/{f}"])
                       for f in Scenario._fields})
    batch = ScenarioBatch(
        scen=scen, hull=hull,
        sites=tuple(FBSite(**d) for d in meta["sites"]),
        names=tuple(meta["names"]), labels=tuple(meta["labels"]),
        gating=tuple(bool(g) for g in meta["gating"]),
        seeds=tuple(int(s) for s in meta["seeds"]))
    n_real = int(meta["n_real"])

    # rebuild the state pytree: shape/dtype template via eval_shape (no
    # compute), then place the saved leaves into it — any drift in the
    # carry inventory (new/renamed/re-shaped SimState leaves, an x64
    # dtype flip the fold check missed) is a structured rejection here
    if jax.dtypes.canonicalize_dtype(np.int64) == jnp.int64:
        seeds = jnp.asarray(batch.seeds, jnp.int64)
    else:
        seeds = jnp.asarray([s & 0xFFFFFFFF for s in batch.seeds],
                            jnp.uint32)
    tmpl = jax.eval_shape(
        jax.vmap(lambda sc, k: _init_state(hull, sc, k)),
        scen, jax.eval_shape(jax.vmap(jax.random.PRNGKey), seeds))
    tmpl_leaves, treedef = jax.tree_util.tree_flatten_with_path(tmpl)
    state_leaves = []
    for p_, leaf in tmpl_leaves:
        name = "state" + jax.tree_util.keystr(p_)
        if name not in arrays:
            reject("state_schema", f"carry array {name!r} missing")
        a = arrays[name]
        if tuple(a.shape) != tuple(leaf.shape) \
                or np.dtype(a.dtype) != np.dtype(leaf.dtype):
            reject("state_schema",
                   f"carry array {name!r} is {a.dtype}{a.shape}, this "
                   f"engine expects {leaf.dtype}{tuple(leaf.shape)}")
        state_leaves.append(jnp.asarray(a))
    state = jax.tree_util.tree_unflatten(treedef, state_leaves)

    fdt = _fold_dtype()
    fsum, fcomp = {}, {}
    for k in tmpl.acc:
        for d, store in (("fold_sum", fsum), ("fold_comp", fcomp)):
            name = f"{d}/{k}"
            if name not in arrays:
                reject("state_schema", f"fold buffer {name!r} missing")
            store[k] = jnp.asarray(arrays[name], fdt)
    dev_fold = (fsum, fcomp)

    validate = bool(meta["validate"])
    guard = tol = None
    if validate:
        if "guard" not in arrays:
            reject("state_schema", "validate guard array missing")
        guard = jnp.asarray(arrays["guard"], jnp.int32)
        tol = jnp.asarray(meta["validate_tol"], jnp.float32)

    # re-pad + re-shard for THIS process's device layout (mirrors
    # _prepare_sweep_args; pad rows are full copies of row 0, bit-inert)
    if _should_shard(n_real, shard):
        n_dev = jax.local_device_count()
        sharding = _scen_sharding()
        pad = (-n_real) % n_dev
        if pad:
            def _pad0(x):
                return jnp.concatenate(
                    [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
            scen = jax.tree.map(_pad0, scen)
            state = jax.tree.map(_pad0, state)
            dev_fold = jax.tree.map(_pad0, dev_fold)
            if guard is not None:
                guard = _pad0(guard)
        scen = jax.device_put(scen, sharding)
        state = jax.device_put(state, sharding)
        dev_fold = jax.device_put(dev_fold, sharding)
        if guard is not None:
            guard = jax.device_put(guard, sharding)

    pend = _dispatch_chunks(
        batch, scen, state, dev_fold, guard, tol,
        n_ticks=int(meta["n_ticks"]), chunk=int(meta["chunk_ticks"]),
        fold="device", validate=validate, n_real=n_real,
        start_chunk=int(meta["chunk_index"]), checkpoint=checkpoint,
        plan_meta=meta.get("plan"))
    return _finish_sweep(pend, return_state=return_state)


def run_sweep(batch: ScenarioBatch, n_ticks: int, *,
              chunk_ticks: int = CHUNK_TICKS, return_state: bool = False,
              fold: str = "device", shard: bool | None = None,
              validate: bool = False,
              validate_tol: float | None = None,
              checkpoint: "CheckpointSpec | None" = None):
    """Run every scenario of ``batch`` for n_ticks us in one vmapped,
    chunk-scanned program; returns one metrics dict per scenario (same
    schema as ``run_sim``, plus the scenario ``label``). With
    ``return_state=True`` also returns the final device state (leaves
    batched over scenarios) — e.g. for conservation audits of in-flight
    packets.

    Compiles once per (hull, batch size, chunk length) and reuses the
    executable across calls; a remainder tail runs the same fixed-length
    chunk under a live-tick mask, so it never adds a trace (see module
    docstring).

    ``fold="device"`` (default) keeps the accumulator fold on device
    and performs exactly one host transfer per run; ``fold="host"`` is
    the legacy per-chunk host fold (parity reference). ``shard=None``
    auto-shards the scenario axis across all local devices when more
    than one is visible; ``shard=False`` forces single-device layout.

    ``validate=True`` compiles in-program guards into every chunk: a
    per-scenario finite-value check over the in-flight queues and
    running totals, and (device-fold path) the conservation identity
    injected == delivered + drops + fault_drops + in-flight within
    ``validate_tol`` (relative; default ``C.VALIDATE_CONS_REL_TOL``).
    A tripped guard raises ``SweepValidationError`` at fetch time,
    naming the failing scenario labels and the FIRST failing chunk
    index — localization without any extra host synchronization (the
    guard is a (B,) int32 riding the fold transfer). Validation changes
    the compiled program (one extra trace per hull/shape) but never the
    simulated dynamics: metric values are identical with it on or off.

    ``checkpoint`` (a :class:`CheckpointSpec`; device fold only)
    snapshots the full carry at the spec's chunk cadence so an
    interrupted run restarts from ``resume_sweep(path)`` bit-identically
    (see the durability contract in ROADMAP.md). Checkpointing only
    OBSERVES the run — the dispatched programs and their results are
    bit-identical with it on or off; each snapshot adds one blessed
    host transfer (``HOST_TRANSFER_COUNT`` becomes
    ``1 + n_checkpoints``).
    """
    return _finish_sweep(
        _start_sweep(batch, n_ticks, chunk_ticks=chunk_ticks, fold=fold,
                     shard=shard, validate=validate,
                     validate_tol=validate_tol, checkpoint=checkpoint),
        return_state=return_state)


def run_sweep_planned(runs: Sequence[tuple[SimParams, int]], n_ticks: int,
                      *, max_compiles: int = 4,
                      chunk_ticks: int = CHUNK_TICKS,
                      return_plan: bool = False, fold: str = "device",
                      shard: bool | None = None, pipeline: bool = True,
                      validate: bool = False,
                      validate_tol: float | None = None,
                      retry: "BucketRetryPolicy | None" = None,
                      checkpoint: "CheckpointSpec | None" = None):
    """Run a heterogeneous-site sweep through the hull-bucketing planner
    (core/planner.py): the (SimParams, seed) pairs are partitioned into
    <= ``max_compiles`` hull buckets by estimated padded cost, each
    bucket runs as its own ``make_multi_site_batch`` + sweep dispatch
    (one trace per (hull, batch-shape, chunk), exactly as before), and
    the per-scenario metric dicts come back in CALLER order, each
    annotated with its ``plan_bucket`` index and ``plan_hull`` tag.

    With ``pipeline=True`` (default) the buckets are executed as an
    async pipeline: every bucket's chunk programs are dispatched first,
    in the planner's ``dispatch_order`` (largest padded cost first, so
    tracing/compiling bucket k+1 overlaps device execution of bucket
    k), then results are fetched — one blocking transfer per bucket,
    after all device work is enqueued. Note the pipeline keeps every
    bucket's state + fold buffers resident at once; ``pipeline=False``
    runs buckets strictly serially (dispatch+fetch per bucket, caller
    order, one bucket resident at a time — the low-memory mode for
    accelerators) and is bit-identical: same programs, same inputs.

    With ``return_plan=True`` also returns the plan's padding-waste
    report (``SweepPlan.report()``: per-bucket waste fractions, the
    total padded cost, and the savings vs the single-hull K=1 path).

    ``max_compiles=1`` is the degenerate single-hull case — identical
    to ``run_sweep(make_multi_site_batch(runs), ...)`` (pinned by
    tests/test_planner.py).

    Bucket failures are ISOLATED: an exception while dispatching or
    fetching one bucket (a poisoned scenario tripping ``validate``
    guards, a compile failure, an OOM) never takes down the other
    buckets. The failed bucket is retried per the ``retry`` policy
    (:class:`BucketRetryPolicy`; default = the original contract, ONE
    immediate retry, no deadline), each retry strictly serial on the
    legacy ``fold="host"`` path (the most conservative execution mode:
    per-chunk synchronization, no device-resident fold buffer), with
    the policy's exponential backoff between attempts and its
    ``deadline_s`` bounding each bucket's cumulative wall-clock time —
    once a bucket has spent its deadline, remaining retries are
    abandoned (finished work is never discarded). On exhaustion that
    bucket's runs come back as structured error entries — ``{"label",
    "plan_bucket", "plan_hull", "error": {"type", "message", "stage",
    "retried"}}`` with ``stage`` the phase of the ORIGINAL failure
    ("dispatch" or "fetch") and ``message`` the final attempt's — in
    caller order alongside the successful buckets' metric dicts, so one
    bad scenario degrades exactly its own bucket and nothing else. All
    remaining pending buckets are drained even when a fetch raises, so
    no device buffers are left dangling.

    ``checkpoint`` checkpoints every bucket under a per-bucket tag
    (``<tag>-<plan.bucket_tag(k)>``, collision-free across plans), and
    guarantees graceful partial-result degradation: an exhausted bucket
    additionally carries ``error["checkpoint"]`` — the path of its
    newest cadence snapshot, or a freshly written chunk-0 snapshot of
    its initial carry when it never reached a boundary — so a failed
    planned sweep always leaves every other bucket's results PLUS a
    ``resume_sweep``-able artifact for the failed one (None only if
    even the salvage write failed).
    """
    # local import: the planner is deliberately jax-free and usable
    # standalone; only the execution path needs it
    from repro.core import planner

    if checkpoint is not None and fold != "device":
        raise ValueError(
            "checkpointing requires the device-resident fold "
            f"(fold='device'); got fold={fold!r}")
    runs = list(runs)
    plan = planner.plan_sites([p.site for p, _ in runs], max_compiles)
    order = plan.dispatch_order if pipeline \
        else tuple(range(len(plan.buckets)))
    policy = retry if retry is not None else BucketRetryPolicy()
    pending: dict[int, _PendingSweep] = {}
    fetched: dict[int, list] = {}
    errors: dict[int, dict] = {}
    elapsed: dict[int, float] = {}

    def hook(k, phase):
        if BUCKET_FAIL_HOOK is not None:
            BUCKET_FAIL_HOOK(k, phase)

    def timed(k, fn):
        # per-bucket wall-clock ledger: cumulative across the bucket's
        # dispatch, fetch and retry attempts; the policy's deadline_s
        # is checked against it before each retry
        t0 = time.monotonic()
        try:
            return fn()
        finally:
            elapsed[k] = elapsed.get(k, 0.0) + (time.monotonic() - t0)

    def bucket_spec(k):
        if checkpoint is None:
            return None
        return dataclasses.replace(
            checkpoint, tag=f"{checkpoint.tag}-{plan.bucket_tag(k)}")

    def bucket_plan_meta(k):
        return {"fingerprint": plan.fingerprint, "bucket": k,
                "hull": full_site_tag(plan.buckets[k].hull)}

    def salvage_checkpoint(k, spec_k):
        # a resumable artifact for the exhausted bucket: its newest
        # cadence snapshot if it reached a boundary, else a fresh
        # chunk-0 snapshot of its INITIAL carry (resuming that replays
        # the whole bucket). Best-effort: None if even this fails.
        existing = _ckpt.latest_checkpoint(spec_k.directory, spec_k.tag)
        if existing is not None:
            return str(existing)
        try:
            batch = make_multi_site_batch(
                [runs[i] for i in plan.buckets[k].indices])
            scen, state, dev_fold, guard, tol = _prepare_sweep_args(
                batch, fold="device", shard=shard, validate=validate,
                validate_tol=validate_tol)
            return str(_snapshot_sweep(
                spec_k, batch, 0, state, dev_fold, guard,
                n_ticks=n_ticks,
                chunk=max(1, min(chunk_ticks, n_ticks)),
                validate=validate, tol=tol, n_real=len(batch),
                plan_meta=bucket_plan_meta(k)))
        except Exception:                  # noqa: BLE001 — best effort
            return None

    def retry_bucket(k, stage, exc):
        # bounded retries on the most conservative path; on exhaustion
        # record a structured error for the bucket (stage = the
        # ORIGINAL failure's phase, message = the final failure's)
        last = exc
        retried = False
        for attempt in range(1, policy.max_retries + 1):
            if (policy.deadline_s is not None
                    and elapsed.get(k, 0.0) >= policy.deadline_s):
                break
            delay = policy.backoff_s(attempt)
            if delay > 0.0:
                RETRY_SLEEP(delay)
            retried = True

            def one_retry():
                hook(k, "retry")
                batch = make_multi_site_batch(
                    [runs[i] for i in plan.buckets[k].indices])
                return _finish_sweep(_start_sweep(
                    batch, n_ticks, chunk_ticks=chunk_ticks,
                    fold="host", shard=shard, validate=validate,
                    validate_tol=validate_tol))

            try:
                fetched[k] = timed(k, one_retry)
                return
            except Exception as exc2:      # noqa: BLE001 — isolation
                last = exc2
        errors[k] = {"type": type(last).__name__, "message": str(last),
                     "stage": stage, "retried": retried}
        spec_k = bucket_spec(k)
        if spec_k is not None:
            errors[k]["checkpoint"] = salvage_checkpoint(k, spec_k)

    try:
        for k in order:
            bucket = plan.buckets[k]

            def dispatch(k=k, bucket=bucket):
                hook(k, "dispatch")
                batch = make_multi_site_batch(
                    [runs[i] for i in bucket.indices])
                return _start_sweep(
                    batch, n_ticks, chunk_ticks=chunk_ticks, fold=fold,
                    shard=shard, validate=validate,
                    validate_tol=validate_tol,
                    checkpoint=bucket_spec(k),
                    plan_meta=bucket_plan_meta(k)
                    if checkpoint is not None else None)

            try:
                ps = timed(k, dispatch)
            except Exception as exc:       # noqa: BLE001 — isolation
                retry_bucket(k, "dispatch", exc)
                continue
            if pipeline:
                pending[k] = ps
            else:
                # strictly serial: block on this bucket before the
                # next, and drop ps so its device state/fold buffers
                # free now — this IS the advertised one-bucket-resident
                # memory mode
                try:
                    def fetch(ps=ps, k=k):
                        hook(k, "fetch")
                        return _finish_sweep(ps)
                    fetched[k] = timed(k, fetch)
                except Exception as exc:   # noqa: BLE001 — isolation
                    retry_bucket(k, "fetch", exc)
        for k in (k for k in order if k in pending):
            try:
                def fetch(k=k):
                    hook(k, "fetch")
                    return _finish_sweep(pending.pop(k))
                fetched[k] = timed(k, fetch)
            except Exception as exc:       # noqa: BLE001 — isolation
                retry_bucket(k, "fetch", exc)
    finally:
        # a raising fetch (pre-isolation this propagated) must never
        # leave later buckets' device state/fold buffers referenced
        pending.clear()
    results: list = [None] * len(runs)
    for k, bucket in enumerate(plan.buckets):
        # the FULL tag — the same format the plan report's bucket
        # "hull" field uses, so the two can be joined on it
        hull_tag = full_site_tag(bucket.hull)
        if k in fetched:
            for i, r in zip(bucket.indices, fetched[k]):
                r["plan_bucket"] = k
                r["plan_hull"] = hull_tag
                results[i] = r
        else:
            for i in bucket.indices:
                p, seed = runs[i]
                results[i] = {
                    "label": _run_label(p, seed, tag_site=True),
                    "plan_bucket": k, "plan_hull": hull_tag,
                    "error": dict(errors[k]),
                }
    if return_plan:
        return results, plan.report()
    return results


def _hist_quantile(hist: np.ndarray, q: float,
                   edges: np.ndarray = DELAY_BIN_EDGES_US) -> float:
    """Quantile of a log-binned histogram (default frame:
    DELAY_BIN_EDGES_US; the flow engine passes its FCT / slowdown
    frames), log-linearly interpolated within the crossing bin."""
    total = float(np.sum(hist))
    if total <= 0.0:
        return 0.0
    cdf = np.cumsum(hist) / total
    i = min(int(np.searchsorted(cdf, q)), len(hist) - 1)
    lo_e, hi_e = edges[i], edges[i + 1]
    prev = float(cdf[i - 1]) if i > 0 else 0.0
    frac = (q - prev) / max(float(cdf[i]) - prev, 1e-12)
    frac = min(max(frac, 0.0), 1.0)
    if lo_e <= 0.0:                       # bin 0 is linear [0, MIN)
        return float(hi_e * frac)
    return float(lo_e * (hi_e / lo_e) ** frac)


def _finalize(a: dict, site: FBSite, n_ticks: int, gating_enabled: bool,
              trace: str, label: str | None = None) -> dict:
    """Aggregate accumulators -> the paper's metrics (one scenario).

    ``site`` is the scenario's REAL site (not the batch hull): all link
    populations and power normalizations are the scenario's own.
    """
    s = site
    T = float(n_ticks)

    # ---- latency (Little's law per tier + fixed costs) -----------------
    def wait(backlog, served):
        return float(backlog / max(served, 1e-9))

    inj = max(float(a["injected"]), 1e-9)
    frac_inter = float(a["csw_up_served"]) / inj if inj else 0.0
    mean_wait = (
        wait(a["rsw_backlog"], a["rsw_served"])
        + wait(a["csw_down_backlog"], a["csw_down_served"])
        + frac_inter * (wait(a["csw_up_backlog"], a["csw_up_served"])
                        + wait(a["fc_backlog"], a["fc_served"])))
    ring_frac = float(a["ring_pkts"] + a["fc_ring_pkts"]) / inj
    hops = 4.0 + 2.0 * frac_inter + ring_frac
    mean_latency_us = STACK_US + hops * WIRE_HOP_US + mean_wait

    # ---- delay distribution + attribution (see module docstring) -------
    hist = np.asarray(a["delay_hist"], np.float64)
    wt = max(float(a["delay_wt"]), 1e-9)
    occ = {}
    for tier, n_ports in (("rsw", site.n_racks * site.rsw_uplinks),
                          ("csw", site.n_csw * site.csw_uplinks)):
        n = T * n_ports
        m1 = float(a[f"{tier}_occ_m1"]) / n
        occ[f"{tier}_occ_mean_pkts"] = m1
        occ[f"{tier}_occ_var_pkts"] = max(
            float(a[f"{tier}_occ_m2"]) / n - m1 * m1, 0.0)

    # ---- energy ---------------------------------------------------------
    pw = s.transceiver_power_w()
    rsw_on = float(a["rsw_powered"]) / (T * s.n_rsw_csw_links)
    csw_on = float(a["csw_powered"]) / (T * s.n_csw_fc_links)
    node_on = float(a["node_on"]) / (T * s.n_servers)
    if not gating_enabled:
        node_on = rsw_on = csw_on = 1.0

    # Fig 9 metric: the stage-gated switch-tier transceivers (RSW-CSW and
    # CSW-FC). Stage 1 never gates, so 75% is the ceiling.
    switch_w = pw["rsw_csw"] * rsw_on + pw["csw_fc"] * csw_on
    switch_total = pw["rsw_csw"] + pw["csw_fc"]
    switch_savings = 1.0 - switch_w / switch_total

    # All transceivers (feeds the Fig 11 whole-DC estimate): server links
    # gated by the node-level OS mechanism + switch tiers + always-on rings.
    power_w = pw["server"] * node_on + switch_w + pw["ring"]
    total_w = s.total_transceiver_power_w()

    return {
        "trace": trace,
        "label": label or trace,
        "gating": gating_enabled,
        "ticks": n_ticks,
        "mean_latency_us": mean_latency_us,
        "mean_wait_us": float(mean_wait),
        "wait_rsw_us": wait(a["rsw_backlog"], a["rsw_served"]),
        "wait_csw_up_us": wait(a["csw_up_backlog"], a["csw_up_served"]),
        "wait_csw_down_us": wait(a["csw_down_backlog"],
                                 a["csw_down_served"]),
        "wait_fc_us": wait(a["fc_backlog"], a["fc_served"]),
        "injected_pkts": float(a["injected"]),
        "delivered_pkts": float(a["csw_down_served"]),
        "drop_frac": float(a["drops"]) / inj,
        # availability under faults: delivered fraction, the fault-drop
        # conservation bin, wake-retry/fallback counts, and the
        # connectivity-loss audit (all exactly 0 with zero fault knobs)
        "delivered_frac": float(a["csw_down_served"]) / inj,
        "fault_drop_frac": float(a["fault_drops"]) / inj,
        "fault_dropped_pkts": float(a["fault_drops"]),
        "wake_retries": float(a["wake_retries"]),
        "forced_wakes": float(a["forced_wakes"]),
        "conn_loss_rack_ticks": float(a["conn_loss_rack_ticks"]),
        "conn_loss_csw_ticks": float(a["conn_loss_csw_ticks"]),
        "conn_loss_ticks": float(a["conn_loss_rack_ticks"]
                                 + a["conn_loss_csw_ticks"]),
        # fraction of gated-link-ticks spent hard-faulted (availability)
        "link_fault_frac": float(a["fault_link_ticks"])
        / (T * (s.n_rsw_csw_links + s.n_csw_fc_links)),
        "ring_frac": ring_frac,
        "rsw_link_on_frac": rsw_on,
        "csw_link_on_frac": csw_on,
        "node_link_on_frac": node_on,
        "switch_energy_savings_frac": float(switch_savings),
        "transceiver_power_w": float(power_w),
        "all_transceiver_savings_frac": float(1.0 - power_w / total_w),
        "half_off_frac": float(a["half_off_ticks"]) / T,
        "on_frac_hist": (a["on_frac_hist"] / T).tolist(),
        "offered_load_pkts_per_tick": inj / T,
        # in-scan delay distribution (normalized; bins in
        # DELAY_BIN_EDGES_US) + percentiles + the attribution split
        "delay_hist": (hist / wt).tolist(),
        "delay_p50_us": _hist_quantile(hist, 0.50),
        "delay_p95_us": _hist_quantile(hist, 0.95),
        "delay_p99_us": _hist_quantile(hist, 0.99),
        "delay_mean_sampled_us": float(a["delay_sum"]) / wt,
        "delay_queue_us": float(a["delay_queue_sum"]) / wt,
        "delay_wake_stall_us": float(a["delay_stall_sum"]) / wt,
        "delay_fault_stall_us": float(a["delay_fault_sum"]) / wt,
        "delay_ring_us": ring_frac * WIRE_HOP_US,
        "delay_frac_inter": float(a["delay_wt_inter"]) / wt,
        "wake_stall_frac": float(a["wake_stall_pkts"]) / wt,
        "fault_stall_frac": float(a["fault_stall_pkts"]) / wt,
        **occ,
        **_finalize_flows(a),
    }


def _finalize_flows(a: dict) -> dict:
    """Flow-engine metrics (all exactly 0 / empty-normalized at
    flow_mode=0, where every flow accumulator is exactly zero):
    per-size-class FCT p50/p99 + slowdown percentiles vs the
    ideal-bandwidth baseline, and the flow-conservation census."""
    fct_hist = np.asarray(a["fct_hist"], np.float64)       # (3, bins)
    slow_hist = np.asarray(a["fct_slow_hist"], np.float64)
    started = float(a["flows_started"])
    completed = float(a["flows_completed"])
    n_done = max(completed, 1e-9)
    out = {
        "flows_started": started,
        "flows_completed": completed,
        "flows_evicted": float(a["flows_evicted"]),
        "flow_evicted_frac": float(a["flows_evicted"])
        / max(started, 1e-9),
        "fct_mean_us": float(a["fct_sum"]) / n_done,
        "fct_slowdown_mean": float(a["fct_slow_sum"]) / n_done,
        # aggregate (all classes) percentiles
        "fct_p50_us": _hist_quantile(fct_hist.sum(0), 0.50,
                                     FCT_BIN_EDGES_US),
        "fct_p99_us": _hist_quantile(fct_hist.sum(0), 0.99,
                                     FCT_BIN_EDGES_US),
        "fct_slowdown_p50": _hist_quantile(slow_hist.sum(0), 0.50,
                                           FCT_SLOWDOWN_BIN_EDGES),
        "fct_slowdown_p99": _hist_quantile(slow_hist.sum(0), 0.99,
                                           FCT_SLOWDOWN_BIN_EDGES),
        # normalized per-class slowdown distributions (rows in
        # FLOW_CLASS_NAMES order, bins in FCT_SLOWDOWN_BIN_EDGES)
        "fct_slow_hist": (slow_hist / n_done).tolist(),
    }
    for c, cname in enumerate(workloads.FLOW_CLASS_NAMES):
        out[f"flows_completed_{cname}"] = float(fct_hist[c].sum())
        out[f"fct_p50_us_{cname}"] = _hist_quantile(
            fct_hist[c], 0.50, FCT_BIN_EDGES_US)
        out[f"fct_p99_us_{cname}"] = _hist_quantile(
            fct_hist[c], 0.99, FCT_BIN_EDGES_US)
        out[f"fct_slowdown_p50_{cname}"] = _hist_quantile(
            slow_hist[c], 0.50, FCT_SLOWDOWN_BIN_EDGES)
        out[f"fct_slowdown_p99_{cname}"] = _hist_quantile(
            slow_hist[c], 0.99, FCT_SLOWDOWN_BIN_EDGES)
    return out


def _sim_program(hull: FBSite, scen: Scenario, n_ticks: int):
    """Build the single-scenario jitted program ``run_sim`` executes.

    A module-level lowering seam: the artifact auditor
    (repro.analysis.artifact) AOT-lowers exactly this program — not a
    re-derived lookalike — so the audited HLO is the HLO run_sim runs.
    ``scen`` leaves are concrete 0-d arrays that close over the step as
    per-scenario constants (the pre-sweep specialization behaviour).
    """
    step = make_sim_step(hull)

    @jax.jit
    def go(state):
        out, _ = jax.lax.scan(lambda st, _: (step(scen, st), None),
                              state, None, length=n_ticks)
        return out

    return go


def run_sim(params: SimParams, n_ticks: int, seed: int = 0) -> dict:
    """Run ONE scenario for n_ticks us; returns aggregate metrics.

    Kept for unit runs and ablations, and deliberately preserves the
    pre-sweep engine's behaviour: the scenario knobs are baked into the
    trace as constants, so every distinct scenario lowers to its own
    jaxpr and pays a fresh specialize-and-compile (no cross-scenario
    cache reuse, no batching, no chunking). Serial loops over scenarios
    therefore scale wall-clock with compile count — use ``run_sweep``
    for sweeps, which traces once for the whole batch.
    """
    batch = make_batch([(params, seed)])
    hull = batch.hull          # == the site's own exact dims
    scen = jax.tree.map(lambda x: x[0], batch.scen)
    state = _init_state(hull, scen, jax.random.PRNGKey(seed))
    go = _sim_program(hull, scen, n_ticks)

    # repro-lint: disable=RL003(single-scenario debug path: one fetch per run_sim call, outside the sweep engine's HOST_TRANSFER_COUNT budget)
    acc = jax.device_get(go(state).acc)
    return _finalize({k: np.asarray(v, np.float64) for k, v in acc.items()},
                     batch.sites[0], n_ticks, batch.gating[0],
                     batch.names[0], batch.labels[0])


def compare_traces(n_ticks: int = 200_000, seed: int = 0,
                   traces=None) -> dict:
    """LC/DC vs always-on across every modeled trace (Figs 8-10), as a
    single batched sweep (one compile, 2x|traces| scenarios)."""
    names = list(traces or TRAFFIC_SPECS)
    runs = []
    for name in names:
        spec = TRAFFIC_SPECS[name]
        runs.append((SimParams(spec=spec, gating_enabled=True), seed))
        runs.append((SimParams(spec=spec, gating_enabled=False), seed))
    res = run_sweep(make_batch(runs), n_ticks)
    out = {}
    for i, name in enumerate(names):
        lc, base = res[2 * i], res[2 * i + 1]
        out[name] = {
            "lcdc": lc, "baseline": base,
            "switch_energy_savings": lc["switch_energy_savings_frac"],
            "all_transceiver_savings": lc["all_transceiver_savings_frac"],
            "latency_penalty":
                lc["mean_latency_us"] / base["mean_latency_us"] - 1.0,
        }
    return out
