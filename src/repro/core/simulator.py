"""LC/DC network simulator: 1 us-slotted, fully vectorized, lax.scan-jitted.

Models the Fig 2 Facebook-style site end to end:

  server NICs --(node-gated links)--> RSW --(stage-gated uplinks)--> CSW
      --(stage-gated 40G uplinks)--> FC --> CSW --> RSW --> server

Edge traffic is stochastic (per-rack flow slots driven by core/traffic.py:
lognormal sizes, ON/OFF bursts); the aggregation tiers are fluid (float
packet counts) which preserves the queue dynamics that drive the
watermark controller while keeping the whole site one dense-array state.

Down-routing honours the stage invariant: packets that land on a CSW/FC
whose downlink to the destination is gated off migrate over the cluster /
FC load-balancing rings (the rings exist for exactly this in Fig 2) to
the always-on stage-1 path, paying ring latency. Connectivity is never
lost because stage >= 1 everywhere (the paper's core invariant).

Latency is measured with Little's law per queue group (mean delay =
mean backlog / delivered rate) plus fixed per-hop wire/pipeline/stack
latencies; the paper reports mean packet delivery latency, which this
estimates directly.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import gating
from repro.core.topology import FBSite
from repro.core.traffic import (TRAFFIC_SPECS, TrafficSpec,
                                rack_flow_rate_per_tick)

F_SLOTS = 64              # concurrent flow slots per rack
NODE_IDLE_TICKS = 50      # server-link idle timeout (us)
RING_CAP = 8              # pkts/tick cluster ring budget
FC_RING_CAP = 16
WIRE_HOP_US = 0.5         # fiber + switch pipeline per hop
STACK_US = 3.75           # TCP/IP + NIC (Sec IV-C)


class SimState(NamedTuple):
    key: jax.Array
    burst_on: jax.Array        # (R,) bool
    flow_rem: jax.Array        # (R, F) int32 remaining packets
    flow_dest: jax.Array       # (R, F) int32 0=rack 1=cluster 2=inter
    flow_fast: jax.Array       # (R, F) bool: line-rate elephant
    rsw_q: jax.Array           # (R, L, 2) float [intra, inter]
    csw_up_q: jax.Array        # (NC, L) float
    csw_down_q: jax.Array      # (NC, RPC) float
    fc_down_q: jax.Array       # (NF, NC) float
    rsw_gate: gating.GateState
    csw_gate: gating.GateState
    node_on: jax.Array         # (R,) float servers-links held on
    acc: dict                  # accumulators


@dataclass(frozen=True)
class SimParams:
    spec: TrafficSpec
    site: FBSite = FBSite()
    gating_enabled: bool = True
    rate_scale: float = 1.0
    queue_cap: float = C.QUEUE_CAP_PKTS
    hi: float = C.HI_WATERMARK
    lo: float = C.LO_WATERMARK
    dwell: int = C.STAGE_DWELL_TICKS


def _init_state(params: SimParams, key) -> SimState:
    s = params.site
    R, L = s.n_racks, s.rsw_uplinks
    NC, RPC, NF = s.n_csw, s.racks_per_cluster, s.n_fc
    rsw_gate = gating.gate_init(R, L)
    csw_gate = gating.gate_init(NC, s.csw_uplinks)
    if not params.gating_enabled:
        full = jnp.full((R,), L, jnp.int32)
        rsw_gate = rsw_gate._replace(
            stage=full, powered=jnp.ones((R, L), bool))
        csw_gate = csw_gate._replace(
            stage=jnp.full((NC,), s.csw_uplinks, jnp.int32),
            powered=jnp.ones((NC, s.csw_uplinks), bool))
    acc = {
        "rsw_backlog": jnp.zeros(()), "rsw_served": jnp.zeros(()),
        "csw_up_backlog": jnp.zeros(()), "csw_up_served": jnp.zeros(()),
        "csw_down_backlog": jnp.zeros(()), "csw_down_served": jnp.zeros(()),
        "fc_backlog": jnp.zeros(()), "fc_served": jnp.zeros(()),
        "ring_pkts": jnp.zeros(()), "fc_ring_pkts": jnp.zeros(()),
        "injected": jnp.zeros(()), "intra_rack": jnp.zeros(()),
        "drops": jnp.zeros(()),
        "rsw_powered": jnp.zeros(()), "csw_powered": jnp.zeros(()),
        "node_on": jnp.zeros(()),
        "half_off_ticks": jnp.zeros(()),
        "on_frac_hist": jnp.zeros((4,)),   # (0-25,25-50,50-75,75-100]% on
    }
    return SimState(
        key=key,
        burst_on=jnp.ones((R,), bool),
        flow_rem=jnp.zeros((R, F_SLOTS), jnp.int32),
        flow_dest=jnp.zeros((R, F_SLOTS), jnp.int32),
        flow_fast=jnp.zeros((R, F_SLOTS), bool),
        rsw_q=jnp.zeros((R, L, 2)),
        csw_up_q=jnp.zeros((NC, s.csw_uplinks)),
        csw_down_q=jnp.zeros((NC, RPC)),
        fc_down_q=jnp.zeros((NF, NC)),
        rsw_gate=rsw_gate, csw_gate=csw_gate,
        node_on=jnp.zeros((R,)),
        acc=acc,
    )


def _spawn_flows(params: SimParams, key, burst_on, flow_rem, flow_dest,
                 flow_fast):
    """Per-rack flow arrivals: Bernoulli spawn into the first free slot."""
    spec = params.spec
    R = params.site.n_racks
    k1, k2, k3, k4 = jax.random.split(key, 4)

    # ON/OFF burst Markov
    stay_on = jax.random.uniform(k1, (R,)) > spec.p_on_off
    wake = jax.random.uniform(k2, (R,)) < spec.p_off_on
    burst_on = jnp.where(burst_on, stay_on, wake)

    p_spawn = jnp.minimum(
        rack_flow_rate_per_tick(spec, params.site.servers_per_rack)
        * params.rate_scale, 1.0)
    spawn = jax.random.bernoulli(k3, p_spawn, (R,)) & burst_on

    ks, kd = jax.random.split(k4)
    # lognormal mixture sizes -> packets (1250 B per packet)
    km1, km2, km3 = jax.random.split(ks, 3)
    pick = jax.random.bernoulli(km1, spec.size_w, (R,))
    z1 = jax.random.normal(km2, (R,))
    z2 = jax.random.normal(km3, (R,))
    size_b = jnp.where(pick, jnp.exp(spec.size_mu1 + spec.size_s1 * z1),
                       jnp.exp(spec.size_mu2 + spec.size_s2 * z2))
    size_p = jnp.maximum(jnp.ceil(size_b / 1250.0), 1.0).astype(jnp.int32)

    u = jax.random.uniform(kd, (R,))
    dest = jnp.where(u < spec.p_intra_rack, 0,
                     jnp.where(u < spec.p_intra_rack + spec.p_intra_cluster,
                               1, 2)).astype(jnp.int32)

    free = flow_rem == 0
    first_free = jnp.argmax(free, axis=1)               # (R,)
    has_free = jnp.any(free, axis=1)
    do = spawn & has_free
    rows = jnp.arange(R)
    flow_rem = flow_rem.at[rows, first_free].add(
        jnp.where(do, size_p, 0))
    flow_dest = flow_dest.at[rows, first_free].set(
        jnp.where(do, dest, flow_dest[rows, first_free]))
    fast = size_p >= spec.elephant_pkts
    flow_fast = flow_fast.at[rows, first_free].set(
        jnp.where(do, fast, flow_fast[rows, first_free]))
    return burst_on, flow_rem, flow_dest, flow_fast


def make_sim_step(params: SimParams):
    s = params.site
    R, L = s.n_racks, s.rsw_uplinks
    NC, RPC, NF = s.n_csw, s.racks_per_cluster, s.n_fc
    CPC = s.csw_per_cluster
    n_clusters = s.n_clusters

    def step(state: SimState, _):
        acc = dict(state.acc)
        key, k_spawn, k_pace = jax.random.split(state.key, 3)

        # 1. traffic edge ------------------------------------------------
        burst_on, flow_rem, flow_dest, flow_fast = _spawn_flows(
            params, k_spawn, state.burst_on, state.flow_rem,
            state.flow_dest, state.flow_fast)
        active = flow_rem > 0                                   # (R,F)
        # paced emission: mice trickle below line rate (boosted during
        # bursts); elephants transmit at line rate -- overlapping
        # elephants are what push queues over the high watermark.
        pace_eff = jnp.minimum(
            params.spec.pace * jnp.where(burst_on,
                                         params.spec.burst_pace_boost, 1.0),
            1.0)[:, None]
        pace_flow = jnp.where(flow_fast,
                              params.spec.elephant_pace, pace_eff)
        emit = active & (jax.random.uniform(k_pace, active.shape)
                         < pace_flow)
        n_holding = jnp.sum(active, axis=1).astype(jnp.float32)  # (R,)
        by_dest = jnp.stack(
            [jnp.sum(emit & (flow_dest == d), axis=1) for d in (0, 1, 2)],
            axis=1).astype(jnp.float32)                          # (R,3)
        flow_rem = jnp.maximum(flow_rem - emit.astype(jnp.int32), 0)
        acc["injected"] += jnp.sum(by_dest[:, 1:])
        acc["intra_rack"] += jnp.sum(by_dest[:, 0])

        # 2. RSW enqueue: min-backlog active uplink ----------------------
        rsw_q = state.rsw_q
        usable = gating.active_mask(state.rsw_gate, L)           # (R,L)
        q_tot = jnp.sum(rsw_q, axis=2)
        masked = jnp.where(usable, q_tot, jnp.inf)
        pick = jnp.argmin(masked, axis=1)                        # (R,)
        rows = jnp.arange(R)
        add = by_dest[:, 1:]                                     # (R,2)
        room = jnp.maximum(params.queue_cap - q_tot[rows, pick], 0.0)
        scale = jnp.minimum(1.0, room / jnp.maximum(add.sum(1), 1e-9))
        acc["drops"] += jnp.sum(add.sum(1) * (1 - scale))
        rsw_q = rsw_q.at[rows, pick].add(add * scale[:, None])

        # 3. RSW serve 1 pkt/tick per powered-active uplink --------------
        srv_mask = usable | (  # a draining link still drains its queue
            (jnp.arange(L)[None, :] == state.rsw_gate.stage[:, None] - 1)
            & state.rsw_gate.draining[:, None])
        q_tot = jnp.sum(rsw_q, axis=2)
        serve = jnp.minimum(q_tot, 1.0) * srv_mask               # (R,L)
        frac = serve / jnp.maximum(q_tot, 1e-9)
        served_split = rsw_q * frac[..., None]                   # (R,L,2)
        rsw_q = rsw_q - served_split
        acc["rsw_backlog"] += jnp.sum(q_tot)
        acc["rsw_served"] += jnp.sum(serve)

        # uplink l of rack r lands on CSW (cluster(r), l)
        srv_rc = served_split.reshape(n_clusters, RPC, L, 2)
        to_csw = jnp.sum(srv_rc, axis=1)                         # (ncl,L,2)
        intra_in = to_csw[..., 0].reshape(NC)                    # (NC,)
        inter_in = to_csw[..., 1].reshape(NC)

        # Stage-aware down-plane weights (the per-stage CAM tables of
        # Sec III-B): traffic for rack r rides plane c with weight
        # active(r,c)/stage(r); dest racks are uniform within the cluster.
        rsw_stage_f = state.rsw_gate.stage.astype(jnp.float32)
        plane_w = (jnp.arange(L)[None, :] < state.rsw_gate.stage[:, None]) \
            / rsw_stage_f[:, None]                               # (R,L)
        plane_w_c = plane_w.reshape(n_clusters, RPC, L)

        # 4. CSW: intra-cluster traffic -> down queues. A packet for rack
        # r arriving UP at csw c may have to cross to plane c' active for
        # r; within a cluster that crossing is the CSW ring. We charge the
        # ring for the mismatch between arrival plane and dest plane.
        intra_cl = jnp.sum(to_csw[..., 0], axis=1)               # (ncl,)
        dest_share = intra_cl[:, None, None] / RPC * \
            plane_w_c.transpose(0, 2, 1)                         # (ncl,L,RPC)
        csw_down_q = state.csw_down_q + dest_share.reshape(NC, RPC)
        # ring charge: fraction of intra traffic whose up-plane != down-plane
        up_share = to_csw[..., 0] / jnp.maximum(intra_cl[:, None], 1e-9)
        mean_down = jnp.mean(plane_w_c, axis=1)                  # (ncl,L)
        same_plane = jnp.sum(jnp.minimum(up_share, mean_down), axis=1)
        acc["ring_pkts"] += jnp.sum(intra_cl * (1.0 - same_plane))

        # inter-cluster -> CSW uplinks (min-backlog among active stages)
        csw_usable = gating.active_mask(state.csw_gate, s.csw_uplinks)
        cmask = jnp.where(csw_usable, state.csw_up_q, jnp.inf)
        cpick = jnp.argmin(cmask, axis=1)                        # (NC,)
        crows = jnp.arange(NC)
        croom = jnp.maximum(params.queue_cap
                            - state.csw_up_q[crows, cpick], 0.0)
        cscale = jnp.minimum(1.0, croom / jnp.maximum(inter_in, 1e-9))
        acc["drops"] += jnp.sum(inter_in * (1 - cscale))
        csw_up_q = state.csw_up_q.at[crows, cpick].add(inter_in * cscale)

        # 5. CSW uplink serve (40G: 4 pkt/tick) -> FC --------------------
        csrv_mask = csw_usable | (
            (jnp.arange(s.csw_uplinks)[None, :]
             == state.csw_gate.stage[:, None] - 1)
            & state.csw_gate.draining[:, None])
        cserve = jnp.minimum(csw_up_q, 4.0) * csrv_mask          # (NC,L)
        csw_up_q = csw_up_q - cserve
        acc["csw_up_backlog"] += jnp.sum(state.csw_up_q)
        acc["csw_up_served"] += jnp.sum(cserve)

        # uplink f of csw c lands on FC f. The FC routes traffic for
        # cluster k down an ACTIVE (f, c') plane of that cluster (per-stage
        # CAMs): weight by the cluster's csw-uplink activity and by the
        # dest rack's active planes.
        fc_in = jnp.sum(cserve, axis=0)                          # (NF,)
        csw_stage_f = state.csw_gate.stage.astype(jnp.float32)
        fc_w = (jnp.arange(NF)[None, :]
                < state.csw_gate.stage[:, None]) / csw_stage_f[:, None]
        # csw c's share of its cluster's down traffic = how much of the
        # cluster's racks ride plane (c mod CPC)
        csw_share = jnp.mean(plane_w_c, axis=1).reshape(NC)      # (NC,)
        # total inter-cluster down traffic splits uniformly over clusters
        down_cl = jnp.sum(fc_in) / n_clusters                    # scalar
        fc_down_add = down_cl * csw_share[None, :] * fc_w.T      # (NF,NC)
        fc_down_q = state.fc_down_q + fc_down_add

        # 6. FC down serve: link (f,c) active iff csw stage[c] > f; any
        #    residual on an inactive plane (stage just dropped) rides the
        #    FC ring to the always-on f=0 plane.
        fc_active = (jnp.arange(NF)[:, None]
                     < state.csw_gate.stage[None, :])            # (NF,NC)
        fserve = jnp.minimum(fc_down_q, 4.0) * fc_active
        fc_down_q = fc_down_q - fserve
        stranded = jnp.where(~fc_active, fc_down_q, 0.0)
        mig = jnp.minimum(jnp.sum(stranded), FC_RING_CAP)
        mfrac = mig / jnp.maximum(jnp.sum(stranded), 1e-9)
        fc_down_q = fc_down_q - stranded * mfrac
        fc_down_q = fc_down_q.at[0, :].add(
            jnp.sum(stranded * mfrac, axis=0))
        acc["fc_ring_pkts"] += mig
        acc["fc_backlog"] += jnp.sum(state.fc_down_q)
        acc["fc_served"] += jnp.sum(fserve)

        # FC-served packets land on csw c -> its down queues, weighted by
        # each rack's active planes (stage-aware, as above)
        per_csw_down = jnp.sum(fserve, axis=0)                   # (NC,)
        pw_cr = plane_w_c.transpose(0, 2, 1).reshape(NC, RPC)    # (NC,RPC)
        pw_norm = pw_cr / jnp.maximum(
            jnp.sum(pw_cr, axis=1, keepdims=True), 1e-9)
        csw_down_q = csw_down_q + per_csw_down[:, None] * pw_norm

        # 7. CSW down serve: link (r, c_in_cluster) active iff rsw
        #    stage[r] > c; stranded traffic rides the cluster ring to c=0.
        rsw_stage = state.rsw_gate.stage.reshape(n_clusters, RPC)
        cidx = jnp.arange(CPC)[None, :, None]                    # cluster pos
        down_act = (cidx < rsw_stage[:, None, :])                # (ncl,CPC,RPC)
        dq = csw_down_q.reshape(n_clusters, CPC, RPC)
        dserve = jnp.minimum(dq, 1.0) * down_act
        dq = dq - dserve
        stranded_d = jnp.where(~down_act, dq, 0.0)               # (ncl,CPC,RPC)
        tot_str = jnp.sum(stranded_d, axis=(1, 2))               # (ncl,)
        migd = jnp.minimum(tot_str, float(RING_CAP))
        dfrac = (migd / jnp.maximum(tot_str, 1e-9))[:, None, None]
        moved = stranded_d * dfrac
        dq = dq - moved
        dq = dq.at[:, 0, :].add(jnp.sum(moved, axis=1))
        csw_down_q = dq.reshape(NC, RPC)
        acc["ring_pkts"] += jnp.sum(migd)
        acc["csw_down_backlog"] += jnp.sum(state.csw_down_q)
        delivered_r = jnp.sum(dserve, axis=1).reshape(R)         # (R,)
        acc["csw_down_served"] += jnp.sum(dserve)

        # 8. node-level link gating (OS intercept: zero latency cost).
        # A server link is held on while its server has active flows (tx)
        # or receives traffic, with an idle timeout.
        need = jnp.minimum(n_holding + delivered_r,
                           float(s.servers_per_rack))
        node_on = jnp.maximum(
            need, state.node_on - s.servers_per_rack / NODE_IDLE_TICKS)
        acc["node_on"] += jnp.sum(node_on)

        # 9. watermark controllers. Per Sec III-B the backlog monitor
        # watches ALL output queues of a switch: the RSW trigger combines
        # its uplink queues with the CSW down-queue pressure on each
        # plane-to-rack link, and the CSW trigger combines its FC uplink
        # queues with the FC down-queue pressure per plane (a saturated
        # 40G down plane must open the next stage).
        rsw_gate, csw_gate = state.rsw_gate, state.csw_gate
        if params.gating_enabled:
            down_rc = csw_down_q.reshape(n_clusters, CPC, RPC) \
                .transpose(0, 2, 1).reshape(R, CPC)          # (R, planes)
            rsw_gate = gating.gate_step(
                rsw_gate, jnp.maximum(jnp.sum(rsw_q, axis=2), down_rc),
                cap=params.queue_cap, hi=params.hi, lo=params.lo,
                dwell=params.dwell)
            csw_gate = gating.gate_step(
                csw_gate, jnp.maximum(csw_up_q, fc_down_q.T),
                cap=params.queue_cap, hi=params.hi, lo=params.lo,
                dwell=params.dwell)

        rsw_pow = jnp.sum(rsw_gate.powered)
        csw_pow = jnp.sum(csw_gate.powered)
        acc["rsw_powered"] += rsw_pow
        acc["csw_powered"] += csw_pow
        frac_on = (rsw_pow + csw_pow) / float(R * L + NC * s.csw_uplinks)
        acc["half_off_ticks"] += (frac_on <= 0.5)
        bucket = jnp.clip((frac_on * 4).astype(jnp.int32), 0, 3)
        acc["on_frac_hist"] = acc["on_frac_hist"].at[bucket].add(1.0)

        new_state = SimState(key, burst_on, flow_rem, flow_dest, flow_fast,
                             rsw_q, csw_up_q, csw_down_q, fc_down_q,
                             rsw_gate, csw_gate, node_on, acc)
        return new_state, None

    return step


def run_sim(params: SimParams, n_ticks: int, seed: int = 0) -> dict:
    """Run the site for n_ticks us; returns aggregate metrics."""
    state = _init_state(params, jax.random.PRNGKey(seed))
    step = make_sim_step(params)

    @jax.jit
    def go(state):
        out, _ = jax.lax.scan(step, state, None, length=n_ticks)
        return out

    final = go(state)
    a = {k: np.asarray(v) for k, v in final.acc.items()}
    s = params.site
    T = float(n_ticks)

    # ---- latency (Little's law per tier + fixed costs) -----------------
    def wait(backlog, served):
        return float(backlog / max(served, 1e-9))

    inj = max(float(a["injected"]), 1e-9)
    frac_inter = float(a["csw_up_served"]) / inj if inj else 0.0
    mean_wait = (
        wait(a["rsw_backlog"], a["rsw_served"])
        + wait(a["csw_down_backlog"], a["csw_down_served"])
        + frac_inter * (wait(a["csw_up_backlog"], a["csw_up_served"])
                        + wait(a["fc_backlog"], a["fc_served"])))
    ring_frac = float(a["ring_pkts"] + a["fc_ring_pkts"]) / inj
    hops = 4.0 + 2.0 * frac_inter + ring_frac
    mean_latency_us = STACK_US + hops * WIRE_HOP_US + mean_wait

    # ---- energy ---------------------------------------------------------
    pw = s.transceiver_power_w()
    rsw_on = float(a["rsw_powered"]) / (T * s.n_rsw_csw_links)
    csw_on = float(a["csw_powered"]) / (T * s.n_csw_fc_links)
    node_on = float(a["node_on"]) / (T * s.n_servers)
    if not params.gating_enabled:
        node_on = rsw_on = csw_on = 1.0

    # Fig 9 metric: the stage-gated switch-tier transceivers (RSW-CSW and
    # CSW-FC). Stage 1 never gates, so 75% is the ceiling.
    switch_w = pw["rsw_csw"] * rsw_on + pw["csw_fc"] * csw_on
    switch_total = pw["rsw_csw"] + pw["csw_fc"]
    switch_savings = 1.0 - switch_w / switch_total

    # All transceivers (feeds the Fig 11 whole-DC estimate): server links
    # gated by the node-level OS mechanism + switch tiers + always-on rings.
    power_w = pw["server"] * node_on + switch_w + pw["ring"]
    total_w = s.total_transceiver_power_w()

    return {
        "trace": params.spec.name,
        "gating": params.gating_enabled,
        "ticks": n_ticks,
        "mean_latency_us": mean_latency_us,
        "mean_wait_us": float(mean_wait),
        "wait_rsw_us": wait(a["rsw_backlog"], a["rsw_served"]),
        "wait_csw_up_us": wait(a["csw_up_backlog"], a["csw_up_served"]),
        "wait_csw_down_us": wait(a["csw_down_backlog"],
                                 a["csw_down_served"]),
        "wait_fc_us": wait(a["fc_backlog"], a["fc_served"]),
        "injected_pkts": float(a["injected"]),
        "delivered_pkts": float(a["csw_down_served"]),
        "drop_frac": float(a["drops"]) / inj,
        "ring_frac": ring_frac,
        "rsw_link_on_frac": rsw_on,
        "csw_link_on_frac": csw_on,
        "node_link_on_frac": node_on,
        "switch_energy_savings_frac": float(switch_savings),
        "transceiver_power_w": float(power_w),
        "all_transceiver_savings_frac": float(1.0 - power_w / total_w),
        "half_off_frac": float(a["half_off_ticks"]) / T,
        "on_frac_hist": (a["on_frac_hist"] / T).tolist(),
        "offered_load_pkts_per_tick": inj / T,
    }


def compare_traces(n_ticks: int = 200_000, seed: int = 0,
                   traces=None) -> dict:
    """LC/DC vs always-on across every modeled trace (Figs 8-10)."""
    out = {}
    for name in (traces or TRAFFIC_SPECS):
        spec = TRAFFIC_SPECS[name]
        lc = run_sim(SimParams(spec=spec, gating_enabled=True),
                     n_ticks, seed)
        base = run_sim(SimParams(spec=spec, gating_enabled=False),
                       n_ticks, seed)
        out[name] = {
            "lcdc": lc, "baseline": base,
            "switch_energy_savings": lc["switch_energy_savings_frac"],
            "all_transceiver_savings": lc["all_transceiver_savings_frac"],
            "latency_penalty":
                lc["mean_latency_us"] / base["mean_latency_us"] - 1.0,
        }
    return out
