"""Timing / power constants from the paper (LC/DC, cs.NI 2021).

Sim tick = 1 us. One 1500 B MTU packet on a 10G link ~= 1.2 us, so a 10G
link serves ~1 pkt/tick and a 40G link 4 pkt/tick.
"""

TICK_US = 1.0

# --- transceiver timing (Sec IV, conservative MRV SFPFC401 [43]) ---------
LASER_ON_US = 1.0          # turn-on
LASER_OFF_US = 10.0        # turn-off (charged at full power: conservative)
CDR_LOCK_US = 0.000625     # clock-phase caching, 625 ps [5,14,15]
SWITCH_STAGE_TRIGGER_NS = 5.8   # FPGA: same-cycle trigger (Sec IV-B)
SWITCH_CTRL_PARSE_NS = 12.8     # 2 cycles @169.32 MHz
SWITCH_PIPELINE_CYCLES = 7
FPGA_CLOCK_MHZ = 169.32

# control-message hop + ack + laser + CDR, rounded up to whole ticks.
# Feasibility (Sec IV): trigger <5.8 ns, ctrl parse 12.8 ns, laser 1 us,
# clock-phase-caching CDR 625 ps, intra-pod fiber ~0.3 us -> ~2 us.
STAGE_UP_DELAY_TICKS = 2
STAGE_OFF_DELAY_TICKS = 10  # 10 us laser-off transition, still charged

# --- node level (Sec IV-C) ------------------------------------------------
TCP_STACK_NS = (950, 260, 550, 430, 400, 760, 400)   # = 3750 ns total
SENDMSG_TO_TX_US = 3.2     # measured mean (100k samples, Sec IV-C)

# --- power (Sec II) -------------------------------------------------------
P_SFP10_W = 1.0            # 10G SFP+ per transceiver
P_QSFP40_W = 2.4           # 40G QSFP per transceiver
P_PHY_W = 0.8              # switch PHY per port
P_NIC_W = 10.0             # server NIC electronics
P_SWITCH_ASIC_W = 28.0     # switch ASIC + CPU chips

# --- in-scan packet-delay histogram (bounded-memory distributions) --------
# Per-tick delay samples are binned into a fixed log-spaced histogram so a
# chunked scan can emit full latency distributions (p50/p95/p99, Fig 10
# tails) without unbounding memory. Bin 0 is [0, MIN); bin i >= 1 covers
# [MIN * 2**((i-1)/BPO), MIN * 2**(i/BPO)); the last bin absorbs overflow.
DELAY_HIST_BINS = 48
DELAY_HIST_MIN_US = 4.0          # just under the 5.75 us stack+wire floor
DELAY_HIST_BINS_PER_OCTAVE = 6   # ~12% resolution per bin, range ~900 us

# --- flow-level workload engine (flow_mode=1, core/workloads.py) ----------
# Fixed per-rack flow-table width: the static slot axis the jitted step
# compiles against. The *usable* prefix is the traced flow_table_cap
# knob (<= this), so table pressure is sweepable with zero recompiles.
FLOW_TABLE_SLOTS = 64
# fixed per-arrival-event size-draw width (the incast fan-in ceiling):
# like MAX_FAULT_LINKS, a fixed draw shape keeps every random stream
# padding- and knob-invariant
MAX_INCAST_DEGREE = 8
# per-flow emission ceiling: 10G NIC ~= 1 pkt/tick — also the line rate
# of the ideal-FCT baseline (workloads.ideal_fct_us)
FLOW_LINE_RATE_PPT = 1.0
# AIMD congestion window (pkts/tick): slow trickle start, additive
# increase toward line rate, halve on the rack's hi-watermark signal
FLOW_CWND_INIT_PPT = 0.25
FLOW_CWND_MIN_PPT = 0.0625
FLOW_AIMD_INCREASE_PPT = 0.02
FLOW_AIMD_DECREASE = 0.5
# FCT histogram: flows live 1e1..1e7 us, so 2 bins/octave spans
# ~8 us * 2**23.5 ~= 9e7 us in the same 48-bin frame the delay
# histogram machinery uses
FCT_HIST_BINS = 48
FCT_HIST_MIN_US = 8.0
FCT_HIST_BINS_PER_OCTAVE = 2
# FCT slowdown histogram (dimensionless, >= 1 by construction):
# 4 bins/octave spans 1x..~3400x
FCT_SLOWDOWN_HIST_BINS = 48
FCT_SLOWDOWN_HIST_MIN = 1.0
FCT_SLOWDOWN_HIST_BINS_PER_OCTAVE = 4

# --- optical fault model (beyond-paper robustness axis) -------------------
# Real optical DCN components are not the paper's perfect plane: wakes
# jitter and transiently fail (PULSE-class timing margins; the Xue et al.
# 2023 optical-switching survey catalogs transceiver reliability). A
# failed stage-up retries after a bounded backoff on top of the re-drawn
# turn-on delay, so a flapping laser cannot hot-loop the controller.
WAKE_RETRY_BACKOFF_TICKS = 4
# conservation tolerance of the opt-in in-program validate guard
# (relative |injected - (delivered + in-flight + drops + fault-drops)|);
# matches the cross-path parity tolerance the test suite pins
VALIDATE_CONS_REL_TOL = 1e-3

# --- watermarks (Sec V) ---------------------------------------------------
QUEUE_CAP_PKTS = 20        # output queue capacity (pkts)
HI_WATERMARK = 0.75        # stage-up threshold (75% buffer utilization)
LO_WATERMARK = 0.22        # stage-down threshold (22%)
# anti-flap dwell: a freshly activated stage stays up for at least this
# long before the low watermark may drain it (keeps an elephant from
# flapping the stage and re-paying the turn-on queueing repeatedly)
STAGE_DWELL_TICKS = 1024

# --- TPU v5e targets for the beyond-paper ICI study & roofline ------------
TPU_PEAK_BF16_FLOPS = 197e12     # per chip
TPU_HBM_BW = 819e9               # bytes/s per chip
TPU_ICI_LINK_BW = 50e9           # bytes/s per link (~ one direction)
TPU_ICI_LINKS_PER_CHIP = 4       # 2D torus (v5e); 3D torus has 6
ICI_XCVR_W = 2.5                 # modeled per-link optical transceiver power
