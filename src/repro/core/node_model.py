"""Node-level LC/DC model (Sec III-C / IV-C).

The kernel interposes ``sendmsg()``: the laser turn-on command is issued
at socket-write time and the payload then spends the TCP/IP + driver +
NIC-DMA pipeline (3.75 us budget, measured 3.2 us mean) before bits hit
the fiber. The laser (1 us) and CDR (625 ps) finish well inside that
window, so the egress link can sit dark between sends at ZERO added
latency. This module reproduces that latency budget and the hiding
condition as executable checks (the kernel module itself is obviously
out of scope for this container; the 200-LoC driver change is described
in the paper).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import constants as C

STACK_STAGES = (
    ("socket write -> TCP entry", 950),
    ("TCP segment + copy to kernel queue", 260),
    ("IP routing / header / driver call", 550),
    ("driver queues descriptor, doorbell", 430),
    ("NIC fetches descriptor (DMA)", 400),
    ("NIC parses descriptor, starts data DMA", 760),
    ("payload cache-line DMA to NIC", 400),
)


@dataclass(frozen=True)
class NodeTiming:
    stack_ns: int
    laser_on_ns: int
    cdr_ns: float

    @property
    def slack_ns(self) -> float:
        return self.stack_ns - (self.laser_on_ns + self.cdr_ns)

    @property
    def hidden(self) -> bool:
        return self.slack_ns >= 0.0

    @property
    def added_latency_ns(self) -> float:
        return max(0.0, -self.slack_ns)


def default_timing() -> NodeTiming:
    return NodeTiming(
        stack_ns=sum(ns for _, ns in STACK_STAGES),
        laser_on_ns=int(C.LASER_ON_US * 1000),
        cdr_ns=C.CDR_LOCK_US * 1000,
    )


def hiding_condition(laser_on_us: float,
                     stack_us: float = C.SENDMSG_TO_TX_US) -> bool:
    """True iff a laser that takes `laser_on_us` is fully hidden behind
    the measured sendmsg->transmit latency."""
    return laser_on_us + C.CDR_LOCK_US <= stack_us


def max_hideable_laser_on_us(stack_us: float = C.SENDMSG_TO_TX_US) -> float:
    return stack_us - C.CDR_LOCK_US
