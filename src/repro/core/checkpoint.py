"""Durable sweep execution: chunk-boundary checkpoint files.

This module is the storage half of the durability contract (see
ROADMAP.md, "Durability contract (as of PR 10)"); the simulator half
(what goes *into* a snapshot and how a run restarts from one) lives in
``core/simulator.py`` (`_snapshot_sweep` / `resume_sweep`).

A checkpoint is one self-contained ``.ckpt.npz`` file holding

* a JSON metadata record (the ``__meta__`` member): schema versions
  (``ckpt_schema`` = :data:`CKPT_SCHEMA_VERSION`, ``sim_schema`` =
  ``simulator.SIM_SCHEMA_VERSION``), the fault/flow knob fingerprints,
  the fold dtype (which pins the JAX_ENABLE_X64 mode), the scenario
  field inventory, the run geometry (n_ticks / effective chunk length /
  chunk index), the full scenario-batch recipe (hull + per-scenario
  sites, names, labels, gating flags, seeds), the validate/tol mode,
  and — for planned sweeps — the plan fingerprint + bucket identity;
* the raw per-scenario carry arrays: every ``SimState`` leaf, the
  device Kahan fold ``(sum, comp)`` buffers, the validation guard, and
  every ``Scenario`` leaf, all stripped of devices-multiple padding.

Invariants enforced here:

* **Atomicity** — files are written to a temp name in the destination
  directory, fsynced, then ``os.replace``d into place, so a crash
  mid-write never leaves a truncated checkpoint under the final name
  (:func:`atomic_write_bytes`; :func:`atomic_write_text` is the same
  primitive for the benchmark baseline / cache JSON writers).
* **Integrity** — a sha256 content checksum over the metadata and
  every array (name, dtype, shape, bytes) is embedded in the metadata
  and re-verified on read; corruption fails fast as a structured
  :class:`CheckpointError` instead of resuming from garbage.
* **Fail-fast mismatch** — every reader raises :class:`CheckpointError`
  with a machine-readable ``reason`` naming the first mismatch
  ("checksum", "ckpt_schema", "sim_schema", "x64_mode", ...) rather
  than a generic exception.

This module deliberately knows nothing about JAX: it moves named numpy
arrays and JSON, so it stays importable (and testable) without tracing
anything — ``simulator`` imports it, never the reverse.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
import tempfile
from dataclasses import dataclass
from pathlib import Path

import numpy as np

#: bump when the on-disk layout changes; resume fails fast on mismatch
#: instead of misinterpreting an old file
CKPT_SCHEMA_VERSION = 1

#: default checkpoint directory (repo-root ``results/checkpoints/``;
#: results/ is gitignored, so checkpoints never land in the tree)
DEFAULT_DIR = Path(__file__).resolve().parents[3] / "results" / "checkpoints"

#: npz member carrying the JSON metadata record
_META_MEMBER = "__meta__"

_SUFFIX = ".ckpt.npz"
_FILE_RE = re.compile(r"^(?P<tag>.+)-(?P<chunk>\d{8})\.ckpt\.npz$")
_TAG_RE = re.compile(r"^[A-Za-z0-9._-]+$")


class CheckpointError(RuntimeError):
    """A checkpoint could not be read or does not match this engine.

    ``reason`` is a stable machine-readable mismatch class — one of
    ``"format"`` (unreadable/truncated file), ``"checksum"`` (content
    checksum mismatch), ``"ckpt_schema"``, ``"sim_schema"``,
    ``"x64_mode"``, ``"fingerprint"`` (fault/flow knob inventory),
    ``"scenario_fields"``, or ``"state_schema"`` (missing/extra/shaped-
    differently carry arrays). ``detail`` is the human-readable
    elaboration naming the exact mismatch.
    """

    def __init__(self, reason: str, detail: str):
        self.reason = reason
        self.detail = detail
        super().__init__(f"checkpoint rejected ({reason}): {detail}")


@dataclass(frozen=True)
class CheckpointSpec:
    """Where and how often a sweep snapshots its carry.

    ``every_chunks`` is a cadence over the sweep's chunk boundaries: a
    snapshot of the full per-scenario carry is taken whenever the
    completed-chunk count is a multiple of it (the final boundary is
    excluded — the run is finished there, not resumable). ``keep``
    bounds the files retained per tag; older cadence snapshots are
    pruned after each successful write. The snapshot fetch is the
    registered blessed host-transfer point, so with a cadence of ``c``
    a run's ``HOST_TRANSFER_COUNT`` is exactly ``1 + n_checkpoints``.
    """

    directory: str | Path = DEFAULT_DIR
    every_chunks: int = 1
    tag: str = "sweep"
    keep: int = 2

    def __post_init__(self):
        def bad(msg: str):
            raise ValueError(f"CheckpointSpec: {msg}")

        if not (isinstance(self.every_chunks, int)
                and self.every_chunks >= 1):
            bad(f"every_chunks must be an int >= 1, got "
                f"{self.every_chunks!r}")
        if not (isinstance(self.keep, int) and self.keep >= 1):
            bad(f"keep must be an int >= 1, got {self.keep!r}")
        if not _TAG_RE.match(str(self.tag)):
            bad(f"tag must match {_TAG_RE.pattern}, got {self.tag!r}")

    def path_for(self, chunk_index: int) -> Path:
        """Checkpoint filename for a snapshot taken at ``chunk_index``
        completed chunks."""
        return Path(self.directory) / f"{self.tag}-{chunk_index:08d}{_SUFFIX}"


def atomic_write_bytes(path: str | Path, data: bytes) -> Path:
    """Write ``data`` to ``path`` via temp-file + fsync + ``os.replace``
    so readers never observe a partial file and an interrupted write
    never clobbers the previous version."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=str(path.parent),
                               prefix=path.name + ".", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path


def atomic_write_text(path: str | Path, text: str) -> Path:
    """Atomic (temp + rename) replacement for ``Path.write_text``."""
    return atomic_write_bytes(path, text.encode("utf-8"))


def _checksum(meta: dict, arrays: dict) -> str:
    """sha256 over the metadata record and every array's identity and
    contents (name, dtype, shape, raw bytes) in sorted-name order."""
    h = hashlib.sha256()
    h.update(json.dumps(meta, sort_keys=True).encode("utf-8"))
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode("utf-8"))
        h.update(str(a.dtype).encode("utf-8"))
        h.update(str(a.shape).encode("utf-8"))
        h.update(a.tobytes())
    return h.hexdigest()


def write_checkpoint(path: str | Path, meta: dict, arrays: dict) -> Path:
    """Atomically write one checkpoint file.

    ``meta`` must be JSON-serializable; ``ckpt_schema`` and the content
    ``checksum`` are stamped here (any caller-provided values are
    overwritten), so every file this function produces is verifiable by
    :func:`read_checkpoint`.
    """
    meta = dict(meta)
    meta.pop("checksum", None)
    meta["ckpt_schema"] = CKPT_SCHEMA_VERSION
    meta["checksum"] = _checksum(
        {k: v for k, v in meta.items() if k != "checksum"}, arrays)
    blob = io.BytesIO()
    np.savez(blob, **{
        _META_MEMBER: np.frombuffer(
            json.dumps(meta, sort_keys=True).encode("utf-8"),
            dtype=np.uint8)}, **arrays)
    return atomic_write_bytes(path, blob.getvalue())


def read_checkpoint(path: str | Path) -> tuple[dict, dict]:
    """Load and verify one checkpoint file -> ``(meta, arrays)``.

    Raises :class:`CheckpointError` with reason ``"format"`` when the
    file is unreadable (truncated zip, missing metadata member, broken
    JSON), ``"ckpt_schema"`` when written by an incompatible layout
    version, or ``"checksum"`` when the content hash does not match.
    """
    path = Path(path)
    try:
        with np.load(path, allow_pickle=False) as z:
            names = list(z.files)
            if _META_MEMBER not in names:
                raise CheckpointError(
                    "format", f"{path}: missing {_META_MEMBER} member")
            meta_raw = bytes(z[_META_MEMBER].tobytes())
            arrays = {n: z[n] for n in names if n != _META_MEMBER}
    except CheckpointError:
        raise
    except Exception as exc:
        raise CheckpointError(
            "format",
            f"{path}: unreadable ({type(exc).__name__}: {exc})") from exc
    try:
        meta = json.loads(meta_raw.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as exc:
        raise CheckpointError(
            "format", f"{path}: metadata is not valid JSON") from exc
    if not isinstance(meta, dict):
        raise CheckpointError(
            "format", f"{path}: metadata is not a JSON object")
    if meta.get("ckpt_schema") != CKPT_SCHEMA_VERSION:
        raise CheckpointError(
            "ckpt_schema",
            f"{path}: written with checkpoint schema "
            f"{meta.get('ckpt_schema')!r}, this engine reads "
            f"{CKPT_SCHEMA_VERSION}")
    want = meta.get("checksum")
    got = _checksum({k: v for k, v in meta.items() if k != "checksum"},
                    arrays)
    if want != got:
        raise CheckpointError(
            "checksum",
            f"{path}: stored {str(want)[:12]}..., recomputed "
            f"{got[:12]}... — file corrupt or tampered")
    return meta, arrays


def list_checkpoints(directory: str | Path,
                     tag: str | None = None) -> list[tuple[int, Path]]:
    """All checkpoint files in ``directory`` (optionally for one tag),
    as ``(chunk_index, path)`` sorted by ascending chunk index."""
    directory = Path(directory)
    if not directory.is_dir():
        return []
    out = []
    for p in directory.iterdir():
        m = _FILE_RE.match(p.name)
        if m is None:
            continue
        if tag is not None and m.group("tag") != tag:
            continue
        out.append((int(m.group("chunk")), p))
    return sorted(out)


def latest_checkpoint(directory: str | Path,
                      tag: str | None = None) -> Path | None:
    """Path of the highest-chunk-index checkpoint, or None."""
    found = list_checkpoints(directory, tag)
    return found[-1][1] if found else None


def prune(spec: CheckpointSpec) -> None:
    """Drop all but the newest ``spec.keep`` checkpoints of this tag.
    Best-effort: a concurrent unlink is not an error."""
    found = list_checkpoints(spec.directory, spec.tag)
    for _, p in found[:-spec.keep]:
        try:
            p.unlink()
        except OSError:
            pass
