"""Data-center network topologies.

`FBSite` is the simulated Clos site of Fig 2 (the LC/DC evaluation
network): 4 clusters x 32 racks x 48 servers, RSW->4 CSWs (10G),
CSW->4 FCs (40G), plus the CSW/FC load-balancing rings.

The Fig 1 power study additionally models a Flattened Butterfly [1] and
three Fat-Tree builds [28] by component count (``component_counts``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

from repro.core import constants as C


@dataclass(frozen=True)
class FBSite:
    """A (generalized) Fig 2 Clos site.

    The wiring fixes two invariants: every RSW has exactly one uplink
    per CSW of its cluster (``rsw_uplinks == csw_per_cluster`` — uplink
    c IS the link to cluster-CSW c, the stage-c "plane"), and every CSW
    has exactly one uplink per fabric core switch (``csw_uplinks ==
    n_fc`` — uplink f IS the link to FC f). The uplink fields therefore
    default to None and are derived; passing them explicitly is allowed
    only when consistent (anything else would silently mis-route the
    down-plane math, so ``__post_init__`` rejects it).
    """
    n_clusters: int = 4
    racks_per_cluster: int = 32
    servers_per_rack: int = 48
    csw_per_cluster: int = 4
    n_fc: int = 4
    rsw_uplinks: int | None = None  # derived: = csw_per_cluster
    csw_uplinks: int | None = None  # derived: = n_fc
    csw_ring_links: int = 8         # 10G per cluster ring
    fc_ring_links: int = 16         # 10G FC ring

    def __post_init__(self):
        if self.rsw_uplinks is None:
            object.__setattr__(self, "rsw_uplinks", self.csw_per_cluster)
        if self.csw_uplinks is None:
            object.__setattr__(self, "csw_uplinks", self.n_fc)
        for name in ("n_clusters", "racks_per_cluster", "servers_per_rack",
                     "csw_per_cluster", "n_fc", "rsw_uplinks",
                     "csw_uplinks"):
            if int(getattr(self, name)) < 1:
                raise ValueError(f"FBSite.{name} must be >= 1, got "
                                 f"{getattr(self, name)}")
        if self.rsw_uplinks != self.csw_per_cluster:
            raise ValueError(
                f"inconsistent FBSite: rsw_uplinks={self.rsw_uplinks} but "
                f"csw_per_cluster={self.csw_per_cluster}; each RSW has one "
                "uplink per cluster CSW (uplink c is the stage-c plane), "
                "so the two must match — omit rsw_uplinks to derive it")
        if self.csw_uplinks != self.n_fc:
            raise ValueError(
                f"inconsistent FBSite: csw_uplinks={self.csw_uplinks} but "
                f"n_fc={self.n_fc}; each CSW has one uplink per fabric "
                "core switch (uplink f lands on FC f), so the two must "
                "match — omit csw_uplinks to derive it")

    @property
    def n_racks(self) -> int:
        return self.n_clusters * self.racks_per_cluster

    @property
    def n_servers(self) -> int:
        return self.n_racks * self.servers_per_rack

    @property
    def n_csw(self) -> int:
        return self.n_clusters * self.csw_per_cluster

    # --- link populations (each link has a transceiver at BOTH ends) ----
    @property
    def n_server_links(self) -> int:
        return self.n_servers

    @property
    def n_rsw_csw_links(self) -> int:
        return self.n_racks * self.rsw_uplinks          # 512

    @property
    def n_csw_fc_links(self) -> int:
        return self.n_csw * self.csw_uplinks            # 64 (40G)

    @property
    def n_ring_links(self) -> int:
        return self.n_clusters * self.csw_ring_links + self.fc_ring_links

    def transceiver_power_w(self) -> dict:
        """Peak (always-on) optical transceiver power by population."""
        return {
            "server": self.n_server_links * 2 * C.P_SFP10_W,
            "rsw_csw": self.n_rsw_csw_links * 2 * C.P_SFP10_W,
            "csw_fc": self.n_csw_fc_links * 2 * C.P_QSFP40_W,
            "ring": self.n_ring_links * 2 * C.P_SFP10_W,
        }

    def total_transceiver_power_w(self) -> float:
        return sum(self.transceiver_power_w().values())


def site_tag(site: FBSite) -> str:
    """Compact ``<ncl>x<rpc>c<cpc>f<nfc>`` tag of the four hull-defining
    axes; used in scenario labels, cache keys and planner reports."""
    return (f"{site.n_clusters}x{site.racks_per_cluster}"
            f"c{site.csw_per_cluster}f{site.n_fc}")


def full_site_tag(site: FBSite) -> str:
    """``site_tag`` extended with servers-per-rack and ring-link counts —
    covers EVERY FBSite field, so two distinct sites never collide."""
    return (f"{site_tag(site)}s{site.servers_per_rack}"
            f"r{site.csw_ring_links}-{site.fc_ring_links}")


def pad_hull(sites: Sequence[FBSite]) -> FBSite:
    """The smallest FBSite every site in ``sites`` fits inside (per-axis
    max). This is the static shape a multi-site batch compiles against;
    the planner (core/planner.py) buckets scenarios to keep these hulls
    tight."""
    return FBSite(
        n_clusters=max(s.n_clusters for s in sites),
        racks_per_cluster=max(s.racks_per_cluster for s in sites),
        servers_per_rack=max(s.servers_per_rack for s in sites),
        csw_per_cluster=max(s.csw_per_cluster for s in sites),
        n_fc=max(s.n_fc for s in sites),
        csw_ring_links=max(s.csw_ring_links for s in sites),
        fc_ring_links=max(s.fc_ring_links for s in sites))


@dataclass(frozen=True)
class NetworkDesign:
    """Component counts for the Fig 1 power-breakdown study."""
    name: str
    n_servers: int
    n_switches: int
    n_10g_ports: int          # optical 10G ports (transceiver each)
    n_40g_ports: int
    notes: str = ""

    def network_power_w(self) -> dict:
        return {
            "switch_asic": self.n_switches * C.P_SWITCH_ASIC_W,
            "nic": self.n_servers * C.P_NIC_W,
            "phy": (self.n_10g_ports + self.n_40g_ports) * C.P_PHY_W,
            "transceivers": (self.n_10g_ports * C.P_SFP10_W
                             + self.n_40g_ports * C.P_QSFP40_W),
        }


def fb_site_design() -> NetworkDesign:
    s = FBSite()
    n10 = (s.n_server_links * 2 + s.n_rsw_csw_links * 2
           + s.n_ring_links * 2)
    n40 = s.n_csw_fc_links * 2
    n_switches = s.n_racks + s.n_csw + s.n_fc
    return NetworkDesign("fb_clos", s.n_servers, n_switches, n10, n40,
                         "Facebook site, Fig 2 [48]")


def flattened_butterfly_design(n_servers: int = 6144) -> NetworkDesign:
    # Abts et al. [1]: FBFLY k=32 c=4; ~each switch 4 servers + ~19
    # inter-switch 40G ports.
    n_sw = n_servers // 4
    n40 = n_sw * 19
    return NetworkDesign("flattened_butterfly", n_servers, n_sw,
                         n_servers * 2, n40, "Google FBFLY [1]")


def fat_tree_designs(n_servers: int = 6144) -> list[NetworkDesign]:
    # Farrington et al. [28]: k=48 3-tier FULLY-PROVISIONED fat trees
    # (1:1 oversubscription): every server has an optical edge link plus
    # edge-agg and agg-core fabric links (2 transceivers each) -> ~6 10G
    # transceivers per server, with a 40G share for the engineered
    # variants. The board/chassis (ft2) and custom-ASIC (ft3) builds fold
    # tiers onto backplanes, cutting optical port counts.
    designs = []
    for i, (sw_scale, p10, p40) in enumerate(
            [(1.0, 6.0, 0.5), (0.7, 5.0, 0.4), (0.5, 4.0, 0.3)], start=1):
        n_sw = int(5 * n_servers / 48 * sw_scale)
        n10 = int(n_servers * p10)
        n40 = int(n_servers * p40)
        designs.append(NetworkDesign(
            f"fat_tree_{i}", n_servers, n_sw, n10, n40,
            "off-the-shelf" if i == 1 else
            ("board/chassis engineered" if i == 2 else "custom ASIC")))
    return designs


def all_designs() -> list[NetworkDesign]:
    return [fb_site_design(), flattened_butterfly_design(),
            *fat_tree_designs()]
