"""Flow-level workload models: heavy-tailed DCN flow-size distributions.

The paper evaluates aggregate packet delay, but modern DCN comparisons
(pFabric, PULSE, the optical-switching surveys) rank architectures on
per-flow-size-class FCT slowdown. This module holds the flow-size CDFs
and the in-scan sampling machinery the simulator's flow engine
(``flow_mode=1``, core/simulator.py) draws from:

* ``websearch``  — the web-search workload of the DCTCP/pFabric papers:
  ~60% of flows under 100 KB but >95% of the *bytes* in flows over 1 MB.
* ``datamining`` — the data-mining workload of VL2/pFabric: ~80% of
  flows under 10 KB with a far heavier tail (up to ~800 MB), so mice
  dominate counts even more and elephants dominate bytes even more.

Both CDFs are stored as (size_pkts, P(size <= s)) anchor tables in
PACKETS (1250 B per packet, the simulator's fluid unit, ~1500 B MTU
minus headers) and sampled by inverse transform with log-linear
interpolation between anchors — sizes are integral (ceil) and >= 1.

Everything here is pure jnp on f32 (bit-exact across x64 modes) and
table-driven: ``CDF_SIZE_PKTS``/``CDF_PROB`` stack every distribution
into one (D, P) constant pair so the *distribution index* can be a
traced scenario knob — one compiled program samples any mix of
distributions across the sweep batch.

Size classes follow the pFabric reporting convention: ``short``
(< ~100 KB), ``medium``, ``long`` (> ~10 MB); edges in
``FLOW_CLASS_EDGES_PKTS``. ``ideal_fct_us`` is the idealized baseline
FCT (line-rate serialization + unloaded path latency) against which
the simulator reports slowdowns.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import constants as C

#: distribution names in CDF table order; the Scenario ``flow_dist``
#: knob is an index into this tuple
FLOW_DIST_NAMES = ("websearch", "datamining")

# CDF anchors as (size_pkts, cum_prob). Published anchor points of the
# DCTCP web-search and VL2 data-mining distributions, converted from
# bytes at 1250 B/pkt and lightly coarsened (log-linear interpolation
# between anchors reproduces the published curves to well under the
# simulator's bin resolution). A repeated size with increasing prob
# encodes an atom (datamining: half of all flows are a single packet).
_WEBSEARCH_CDF = (
    (1, 0.00), (7, 0.15), (15, 0.20), (22, 0.30), (39, 0.40),
    (62, 0.53), (155, 0.60), (779, 0.70), (1557, 0.80),
    (3893, 0.90), (7786, 0.97), (23360, 1.00),
)
_DATAMINING_CDF = (
    (1, 0.00), (1, 0.50), (2, 0.60), (4, 0.70), (8, 0.80),
    (312, 0.90), (2462, 0.95), (77867, 0.99), (778667, 1.00),
)


def _stack_cdfs(*tables):
    """Pad anchor tables to one (D, P) pair of f32 constants (repeating
    each table's last anchor, which is inert under interpolation)."""
    width = max(len(t) for t in tables)
    sizes, probs = [], []
    for t in tables:
        t = tuple(t) + (t[-1],) * (width - len(t))
        sizes.append([s for s, _ in t])
        probs.append([p for _, p in t])
    return (np.asarray(sizes, np.float32), np.asarray(probs, np.float32))


#: (D, P) stacked anchor tables, row order == FLOW_DIST_NAMES
CDF_SIZE_PKTS, CDF_PROB = _stack_cdfs(_WEBSEARCH_CDF, _DATAMINING_CDF)

#: short/medium/long class edges in packets (~100 KB / ~10 MB at
#: 1250 B/pkt) — the pFabric reporting buckets
FLOW_CLASS_EDGES_PKTS = (80, 8000)
FLOW_CLASS_NAMES = ("short", "medium", "long")


def sample_flow_size_pkts(u, dist):
    """Inverse-CDF flow sizes: uniforms ``u`` (any shape, in [0, 1))
    -> integral packet counts (f32, >= 1) from distribution index
    ``dist`` (a scalar int into FLOW_DIST_NAMES; traced is fine — the
    simulator passes the Scenario knob).

    Log-linear interpolation between anchors: within segment
    [(s0, p0), (s1, p1)] the size is s0 * (s1/s0)**frac with
    frac = (u - p0)/(p1 - p0) — monotone in u within and across
    segments, so the sampler itself is monotone (the hypothesis
    property tests/test_flows.py pins). Pure f32, no host branching.
    """
    size_tab = jnp.asarray(CDF_SIZE_PKTS)[dist]          # (P,)
    prob_tab = jnp.asarray(CDF_PROB)[dist]
    u = jnp.asarray(u, jnp.float32)
    npts = CDF_PROB.shape[1]
    # segment index: the last anchor with prob <= u (atoms — repeated
    # sizes — collapse to a zero-length segment whose interp is exact)
    seg = jnp.clip(jnp.sum((u[..., None] >= prob_tab).astype(jnp.int32),
                           axis=-1) - 1, 0, npts - 2)
    lo_s = jnp.take(size_tab, seg)
    hi_s = jnp.take(size_tab, seg + 1)
    lo_p = jnp.take(prob_tab, seg)
    hi_p = jnp.take(prob_tab, seg + 1)
    frac = jnp.clip((u - lo_p) / jnp.maximum(hi_p - lo_p, 1e-9),
                    0.0, 1.0)
    size = lo_s * (hi_s / lo_s) ** frac
    return jnp.maximum(jnp.ceil(size), 1.0)


def flow_size_class(size_pkts):
    """Size-class index (0=short, 1=medium, 2=long) of integral packet
    counts; edges from FLOW_CLASS_EDGES_PKTS, half-open-left (a flow
    exactly at an edge belongs to the smaller class)."""
    lo, hi = FLOW_CLASS_EDGES_PKTS
    s = jnp.asarray(size_pkts)
    return ((s > lo).astype(jnp.int32) + (s > hi).astype(jnp.int32))


def ideal_fct_us(size_pkts, base_path_us):
    """Idealized FCT baseline: unloaded path latency + line-rate
    serialization (C.FLOW_LINE_RATE_PPT pkts/tick, 1 us ticks). The
    denominator of the simulator's FCT slowdown metrics; by
    construction every measured FCT >= this (per-tick flow emission is
    capped at the line rate and path samples are >= the unloaded
    path), so slowdowns are >= 1."""
    return (jnp.asarray(base_path_us, jnp.float32)
            + jnp.asarray(size_pkts, jnp.float32)
            / C.FLOW_LINE_RATE_PPT * C.TICK_US)
