"""Data-center traffic generator (paper Sec V / Fig 6-7).

Models the flow-size and flow-inter-arrival CDFs of
  * Facebook web / cache / Hadoop machines (Roy et al., SIGCOMM'15 [48])
  * Microsoft (VL2 [31] and IMC'09 [36])
  * a university data center (Benson et al., IMC'10 [8])

Each trace is a ``TrafficSpec``: a 2-component lognormal mixture for flow
sizes (bytes), a lognormal for inter-arrival times (us, per server), an
ON/OFF burst modulation, and a destination-locality split. ``TARGET_CDFS``
hold anchor points digitized from the published figures; the paper
validates its generator by the Pearson r between generated and published
CDFs (r = 0.979-0.992 size, 0.894-0.998 interval) and we reproduce that
validation in benchmarks/bench_traffic_cdf.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class TrafficSpec:
    name: str
    # flow size: lognormal mixture  w*LN(mu1,s1) + (1-w)*LN(mu2,s2)  [bytes]
    size_w: float
    size_mu1: float
    size_s1: float
    size_mu2: float
    size_s2: float
    # inter-arrival per server [us]: lognormal
    iat_mu: float
    iat_s: float
    # ON/OFF burst modulation (per-rack Markov, per-tick transition probs)
    p_on_off: float = 0.002     # leave ON
    p_off_on: float = 0.004     # leave OFF
    # destination split
    p_intra_rack: float = 0.3
    p_intra_cluster: float = 0.45   # rest = inter-cluster
    # per-flow packet pacing: emit probability per tick (1.0 = line rate).
    # Real DC flows rarely run at NIC line rate; pacing keeps server links
    # occupied (node-gating realism) without saturating the uplinks.
    pace: float = 0.05
    # pace multiplier while a rack bursts (shuffle/scatter phases)
    burst_pace_boost: float = 1.0
    # flows >= elephant_pkts packets transmit near line rate: overlapping
    # elephants are what push a queue over the high watermark (hadoop
    # shuffle / cache-warm behaviour). Mice keep `pace`. elephant_pace is
    # slightly below 1.0 so a lone elephant still lets the queue drain.
    elephant_pkts: int = 64
    elephant_pace: float = 0.95


# mu/s in ln(bytes). exp(mu) = median flow size.
TRAFFIC_SPECS: dict[str, TrafficSpec] = {
    # Hadoop: small flows dominate (median <1 kB, Roy Fig.5), heavy rack
    # locality; frequent arrivals (median ~2 ms/server).
    "fb_hadoop": TrafficSpec("fb_hadoop", 0.75, np.log(600), 0.9,
                             np.log(100e3), 1.9, np.log(2000), 1.2,
                             p_on_off=0.003, p_off_on=0.0012,
                             p_intra_rack=0.45, p_intra_cluster=0.40,
                             pace=0.03),
    # Web servers: small request/response flows, cluster-heavy traffic.
    "fb_web": TrafficSpec("fb_web", 0.7, np.log(2e3), 1.0,
                          np.log(120e3), 1.6, np.log(3500), 1.1,
                          p_on_off=0.0025, p_off_on=0.0012,
                          p_intra_rack=0.15, p_intra_cluster=0.25,
                          pace=0.04),
    # Cache followers: medium flows, some MB-scale, mostly inter-cluster.
    "fb_cache": TrafficSpec("fb_cache", 0.55, np.log(6e3), 1.1,
                            np.log(500e3), 1.6, np.log(15000), 1.3,
                            p_on_off=0.002, p_off_on=0.0015,
                            p_intra_rack=0.1, p_intra_cluster=0.45,
                            pace=0.04),
    # Microsoft VL2/IMC09: >80 % of flows < 100 kB with a heavy tail;
    # the most demanding load in Fig 8/9.
    "microsoft": TrafficSpec("microsoft", 0.6, np.log(4e3), 1.3,
                             np.log(400e3), 1.8, np.log(6500), 1.5,
                             p_on_off=0.0015, p_off_on=0.002,
                             p_intra_rack=0.2, p_intra_cluster=0.35,
                             pace=0.04),
    # University DC (Benson IMC'10): low utilization, very bursty.
    "university": TrafficSpec("university", 0.8, np.log(1500), 1.2,
                              np.log(200e3), 1.9, np.log(9000), 1.8,
                              p_on_off=0.005, p_off_on=0.001,
                              p_intra_rack=0.35, p_intra_cluster=0.35,
                              pace=0.02),
}


# Anchor points (value, cdf) digitized from the published measurements the
# paper targets. Sizes in bytes, intervals in us (per server).
TARGET_CDFS: dict[str, dict[str, list]] = {
    "fb_hadoop": {
        "size": [(100, 0.05), (300, 0.22), (1e3, 0.62), (3e3, 0.78),
                 (1e4, 0.86), (1e5, 0.94), (1e6, 0.985), (1e7, 0.998)],
        "interval": [(100, 0.03), (500, 0.18), (1e3, 0.34), (2e3, 0.52),
                     (5e3, 0.75), (1e4, 0.87), (1e5, 0.985)],
    },
    "fb_web": {
        "size": [(300, 0.06), (1e3, 0.32), (3e3, 0.60), (1e4, 0.76),
                 (5e4, 0.87), (1e5, 0.92), (1e6, 0.982), (1e7, 0.997)],
        "interval": [(300, 0.04), (1e3, 0.22), (3e3, 0.46), (6e3, 0.66),
                     (2e4, 0.88), (1e5, 0.98)],
    },
    "fb_cache": {
        "size": [(500, 0.04), (2e3, 0.25), (6e3, 0.47), (3e4, 0.63),
                 (1e5, 0.74), (5e5, 0.87), (2e6, 0.95), (2e7, 0.995)],
        "interval": [(500, 0.05), (2e3, 0.25), (6e3, 0.50), (2e4, 0.74),
                     (1e5, 0.93), (1e6, 0.995)],
    },
    "microsoft": {
        "size": [(100, 0.04), (1e3, 0.30), (4e3, 0.52), (2e4, 0.68),
                 (1e5, 0.79), (1e6, 0.91), (1e7, 0.97), (1e8, 0.995)],
        "interval": [(50, 0.05), (200, 0.20), (1e3, 0.47), (5e3, 0.76),
                     (3e4, 0.93), (3e5, 0.992)],
    },
    "university": {
        "size": [(100, 0.06), (500, 0.28), (1500, 0.52), (5e3, 0.70),
                 (3e4, 0.84), (2e5, 0.93), (2e6, 0.98), (2e7, 0.996)],
        "interval": [(500, 0.03), (3e3, 0.2), (1.2e4, 0.5), (5e4, 0.77),
                     (3e5, 0.95), (3e6, 0.997)],
    },
}


def sample_flow_sizes(key, spec: TrafficSpec, n: int) -> jnp.ndarray:
    """Draw n flow sizes [bytes] from the mixture."""
    k1, k2, k3 = jax.random.split(key, 3)
    pick = jax.random.bernoulli(k1, spec.size_w, (n,))
    z = jax.random.normal(k2, (n,))
    s1 = jnp.exp(spec.size_mu1 + spec.size_s1 * z)
    z2 = jax.random.normal(k3, (n,))
    s2 = jnp.exp(spec.size_mu2 + spec.size_s2 * z2)
    return jnp.where(pick, s1, s2)


def sample_intervals(key, spec: TrafficSpec, n: int) -> jnp.ndarray:
    """Draw n inter-arrival times [us per server]."""
    z = jax.random.normal(key, (n,))
    return jnp.exp(spec.iat_mu + spec.iat_s * z)


def empirical_cdf_at(samples: np.ndarray, xs: np.ndarray) -> np.ndarray:
    s = np.sort(np.asarray(samples))
    return np.searchsorted(s, xs, side="right") / len(s)


def pearson_vs_target(samples, anchors) -> float:
    xs = np.array([a[0] for a in anchors], dtype=float)
    target = np.array([a[1] for a in anchors], dtype=float)
    got = empirical_cdf_at(np.asarray(samples, dtype=float), xs)
    gm, tm = got.mean(), target.mean()
    num = np.sum((got - gm) * (target - tm))
    den = np.sqrt(np.sum((got - gm) ** 2) * np.sum((target - tm) ** 2))
    return float(num / den) if den > 0 else 0.0


def stack_specs(specs) -> dict[str, np.ndarray]:
    """Stack TrafficSpec fields into (B,) arrays, one row per scenario.

    The batched sweep engine (core/simulator.py) turns every per-spec
    knob into an array-valued pytree leaf so a single compiled step can
    be vmapped over scenarios; this is the traffic half of that pytree.
    """
    import dataclasses
    out: dict[str, np.ndarray] = {}
    for f in dataclasses.fields(TrafficSpec):
        if f.name == "name":
            continue
        vals = [getattr(s, f.name) for s in specs]
        # f.type is the annotation *string* under future-annotations
        dtype = np.int32 if f.type in (int, "int") else np.float32
        out[f.name] = np.asarray(vals, dtype=dtype)
    return out


def rack_flow_rate_per_tick(spec: TrafficSpec, servers_per_rack: int = 48,
                            duty: float | None = None) -> float:
    """Expected new flows per rack per 1 us tick while the rack is ON."""
    mean_iat_us = float(np.exp(spec.iat_mu + spec.iat_s ** 2 / 2))
    rate = servers_per_rack / mean_iat_us
    if duty is None:
        duty = spec.p_off_on / (spec.p_off_on + spec.p_on_off)
    # compensate for OFF periods so the long-run rate matches the IAT dist
    return rate / max(duty, 1e-6)


def flow_arrival_rate_per_tick(spec: TrafficSpec,
                               servers_per_rack: int = 48,
                               rate_scale: float = 1.0) -> float:
    """Default per-rack flow-ARRIVAL-EVENT rate of the flow engine
    (``flow_mode=1``, P(arrival)/rack/tick, capped at 1): the legacy
    rate-based generator's expected spawn rate under the same
    ``rate_scale``, so the two modes offer comparable load and the
    savings-vs-FCT frontier (benchmarks/bench_flows.py) is an
    apples-to-apples axis. ``SimParams.flow_arrival_rate`` overrides it
    when nonzero."""
    return min(rack_flow_rate_per_tick(spec, servers_per_rack)
               * rate_scale, 1.0)
