"""Data-center power models: Fig 1 (breakdown vs server optimizations),
Fig 9 inputs, and Fig 11 (whole-DC savings of LC/DC).

The server power model follows Fan et al. [26] (component split), SPECpower
SR665 [53] (best-in-class energy proportionality), IRDS CMOS scaling [10,34]
and the memory/storage/specialization optimizations of Sec II. Each
optimization multiplies the affected component's power; the sequence of
bars in Fig 1 is reproduced by ``power_breakdown_series``.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core import constants as C
from repro.core.topology import NetworkDesign, all_designs

SERVER_PEAK_W = 300.0
# peak-power split of a data-center-class server [26]
SERVER_SPLIT = {"cpu": 0.40, "dram": 0.25, "disk": 0.10, "other": 0.25}

# utilization -> power fraction curves (calibrated to the paper's stated
# anchor points: 70% / 58% / 40% of peak at 30% utilization)
UTIL_CURVES = {
    "server_2013": lambda u: 0.50 + 0.6667 * u,      # [6]  70% @30%
    "sr665": lambda u: 0.40 + 0.60 * u,              # [53] 58% @30%
    "proportional": lambda u: 0.10 + 1.00 * u,       # [6,7] 40% @30%
}

# component multipliers per optimization step (applied cumulatively),
# following the Sec II citations: IRDS 7->1.5 nm silicon [10,34], HMC
# [16,46], 16-die 3D NAND [3,55], Catapult-style offload [47], refresh
# reduction [39] + DIMMer idle-off [56], disaggregation [44] + NMP [38].
OPT_STEPS = [
    ("full util (100%)", {}),
    ("2013 server @util", {}),
    ("SR665 @util", {}),
    ("energy-proportional", {}),
    ("CMOS 7->1.5nm", {"cpu": 0.25, "switch_asic": 0.25, "nic": 0.25,
                       "phy": 0.25, "other": 0.5}),
    ("HMC memory", {"dram": 0.4}),
    ("3D-NAND SSD", {"disk": 0.35}),
    ("specialized compute", {"cpu": 0.5}),
    ("DRAM refresh/idle-off", {"dram": 0.5}),
    ("disaggregation+NMP", {"dram": 0.6, "other": 0.6}),
]


def _server_power(util: float, curve: str, mults: dict) -> float:
    base = {k: SERVER_PEAK_W * v for k, v in SERVER_SPLIT.items()}
    for k, m in mults.items():
        if k in base:
            base[k] *= m
    peak = sum(base.values())
    return peak * UTIL_CURVES[curve](util)


def power_breakdown_series(design: NetworkDesign, util: float = 0.30):
    """Fig 1: list of (step_name, breakdown dict in W) for one network."""
    net = design.network_power_w()
    out = []
    cum: dict[str, float] = {}
    for i, (name, mults) in enumerate(OPT_STEPS):
        for k, m in mults.items():
            cum[k] = cum.get(k, 1.0) * m
        if i == 0:
            srv = SERVER_PEAK_W * design.n_servers
        elif i == 1:
            srv = _server_power(util, "server_2013", cum) * design.n_servers
        elif i == 2:
            srv = _server_power(util, "sr665", cum) * design.n_servers
        else:
            srv = _server_power(util, "proportional", cum) * design.n_servers
        netw = dict(net)
        for k in ("switch_asic", "nic", "phy"):
            netw[k] = net[k] * cum.get(k, 1.0)
        row = {"servers": srv, **netw}
        total = sum(row.values())
        out.append((name, row, {k: v / total for k, v in row.items()}))
    return out


def final_network_fractions(util: float = 0.30) -> dict:
    """After all optimizations: transceiver / PHY+NIC+transceiver fraction
    of DC power, per design (the paper projects ~20% / up to 46%)."""
    res = {}
    for d in all_designs():
        series = power_breakdown_series(d, util)
        _, row, frac = series[-1]
        res[d.name] = {
            "transceivers": frac["transceivers"],
            "phy_nic_transceivers": frac["transceivers"] + frac["phy"]
            + frac["nic"],
        }
    return res


@dataclass(frozen=True)
class DCEnergyResult:
    util: float
    transceiver_frac: float            # of total DC power
    savings_links_only: float          # LC/DC gating transceivers
    savings_with_phy_nic: float        # + PHY/NIC electronics sleep


def dc_savings(transceiver_on_frac: float, util: float = 0.30) -> dict:
    """Fig 11: whole-DC savings when LC/DC leaves `transceiver_on_frac`
    of transceiver power on, at the given server utilization, averaged
    over the five network designs (servers fully optimized)."""
    out = {}
    for d in all_designs():
        series = power_breakdown_series(d, util)
        _, row, frac = series[-1]
        total = sum(row.values())
        tx_save = row["transceivers"] * (1 - transceiver_on_frac)
        # extension: PHY + NIC electronics sleep with the link
        ext_save = tx_save + (row["phy"] + row["nic"]) * \
            (1 - transceiver_on_frac)
        out[d.name] = DCEnergyResult(
            util=util,
            transceiver_frac=frac["transceivers"],
            savings_links_only=tx_save / total,
            savings_with_phy_nic=ext_save / total,
        )
    avg_links = sum(r.savings_links_only for r in out.values()) / len(out)
    avg_ext = sum(r.savings_with_phy_nic for r in out.values()) / len(out)
    # the "average" row must carry the real mean transceiver fraction —
    # a 0.0 placeholder silently poisons consumers that average it
    avg_frac = sum(r.transceiver_frac for r in out.values()) / len(out)
    out["average"] = DCEnergyResult(util, avg_frac, avg_links, avg_ext)
    return out
