"""Beyond-paper: LC/DC applied to the TPU pod ICI fabric.

A TPU pod has exactly the properties LC/DC exploits in the data-center
network: per-chip link redundancy (a 2D torus gives 4 ICI links/chip,
two independent ring directions per axis) and bursty, phase-structured
traffic (per-layer collective bursts separated by compute windows,
pipeline bubbles, idle serving periods).

Two policies are evaluated on every (arch x shape) dry-run cell:

  * ``reactive``  - the paper's watermark controller (core/gating.py,
    the very same ``gate_step``) driven by outstanding collective bytes
    per link; pays the turn-on latency when a burst arrives faster than
    the stage can rise.
  * ``scheduled`` - beyond-paper: the training step is a *static*,
    compile-time-known schedule, so the runtime can raise links
    LASER_ON_US ahead of each collective window (the sendmsg-intercept
    trick, but with perfect foresight instead of a 3.2 us heads-up).
    Zero latency cost by construction; energy = collective duty cycle
    plus turn-on/off transition charge.

Inputs come from the dry-run accounting (per-layer HLO flops / HBM bytes
/ collective link-bytes); timings use the v5e constants in
core/constants.py.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path

import numpy as np

from repro.core import constants as C

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


@dataclass(frozen=True)
class StepPhases:
    """One training/serving step as alternating compute/collective phases."""
    arch: str
    shape: str
    n_layers: int
    t_compute_us: float        # per layer
    t_collective_us: float     # per layer
    t_tail_us: float           # embeddings / loss / optimizer tail
    coll_tail_us: float        # gradient all-reduce tail (DP sync)

    @property
    def step_us(self) -> float:
        return (self.n_layers * (self.t_compute_us + self.t_collective_us)
                + self.t_tail_us + self.coll_tail_us)

    @property
    def collective_duty(self) -> float:
        return (self.n_layers * self.t_collective_us + self.coll_tail_us) \
            / max(self.step_us, 1e-12)


def phases_from_dryrun(rec: dict, n_chips: int = 256) -> StepPhases | None:
    """Derive the per-layer phase structure from a dry-run record."""
    acct = rec.get("acct")
    if not acct:
        return None
    per_flops = max(acct["per_layer_flops"], 0.0) / n_chips
    per_bytes = max(acct["per_layer_bytes"], 0.0) / n_chips
    per_coll = max(acct["per_layer_coll_link_bytes"], 0.0) / n_chips
    tail_flops = max(acct["total_flops"]
                     - acct["per_layer_flops"] * _n_scan(rec), 0.0) / n_chips
    tail_coll = max(acct["total_coll_link_bytes"]
                    - acct["per_layer_coll_link_bytes"] * _n_scan(rec),
                    0.0) / n_chips

    links = C.TPU_ICI_LINKS_PER_CHIP
    t_comp = max(per_flops / C.TPU_PEAK_BF16_FLOPS,
                 per_bytes / C.TPU_HBM_BW) * 1e6
    t_coll = per_coll / (links * C.TPU_ICI_LINK_BW) * 1e6
    t_tail = tail_flops / C.TPU_PEAK_BF16_FLOPS * 1e6
    coll_tail = tail_coll / (links * C.TPU_ICI_LINK_BW) * 1e6
    return StepPhases(rec["arch"], rec["shape"], _n_scan(rec),
                      t_comp, t_coll, t_tail, coll_tail)


def _n_scan(rec: dict) -> int:
    a = rec.get("acct", {})
    d = a.get("per_layer_flops", 0.0)
    if d <= 0:
        return 1
    return max(int(round((a["total_flops"] - a["L1"]["cost"]["flops"]) / d))
               + 1, 1)


# ---------------------------------------------------------------------------
# Policies
# ---------------------------------------------------------------------------

def scheduled_policy(ph: StepPhases, *, idle_frac: float = 0.0) -> dict:
    """Compile-time link schedule: links power up LASER_ON_US before each
    collective window and power down after (charged LASER_OFF_US), with
    one link-pair always on (connectivity invariant, carries control).

    idle_frac models serving gaps / pipeline bubbles between steps.
    """
    on_per_burst = ph.t_collective_us + C.LASER_ON_US + C.LASER_OFF_US
    on_us = ph.n_layers * min(on_per_burst,
                              ph.t_compute_us + ph.t_collective_us)
    on_us += min(ph.coll_tail_us + C.LASER_ON_US + C.LASER_OFF_US,
                 ph.coll_tail_us + ph.t_tail_us)
    step = ph.step_us / max(1e-9, 1.0 - idle_frac)   # stretch with idleness

    # one of the 4 links stays up; the other 3 follow the schedule
    links = C.TPU_ICI_LINKS_PER_CHIP
    gated = links - 1
    duty = min(on_us / max(step, 1e-9), 1.0)
    on_frac = (1.0 + gated * duty) / links
    return {
        "policy": "scheduled",
        "step_us": step,
        "collective_duty": ph.collective_duty * (1.0 - idle_frac),
        "link_on_frac": on_frac,
        "ici_energy_savings": 1.0 - on_frac,
        "latency_penalty": 0.0,           # turn-on is pre-scheduled
    }


def _reactive_program(links: int, bw_link_tick: float, tick_us: float,
                      cap_q: float, up_delay: int):
    """Build the jitted watermark-controller timeline program
    ``reactive_policy`` executes.

    A module-level lowering seam: the artifact auditor
    (repro.analysis.artifact) AOT-lowers exactly this program, so the
    audited HLO is the HLO the ICI analysis path runs."""
    import jax
    import jax.numpy as jnp
    from repro.core import gating

    @jax.jit
    def run(demand):
        state = gating.gate_init(1, links)

        def tick(carry, d):
            state, queue, stall = carry
            queue = queue + d
            serve = state.stage[0].astype(jnp.float32) * bw_link_tick
            served = jnp.minimum(queue, serve)
            queue = queue - served
            stall = stall + jnp.where(queue > 0, tick_us, 0.0)
            q = jnp.full((1, links), queue / cap_q
                         * C.QUEUE_CAP_PKTS / links)
            state = gating.gate_step(state, q, up_delay=up_delay, dwell=8)
            return (state, queue, stall), jnp.sum(state.powered)

        (state, queue, stall), powered = jax.lax.scan(
            tick, (state, jnp.zeros(()), jnp.zeros(())),
            jnp.asarray(demand))
        return jnp.sum(powered), stall

    return run


def reactive_policy(ph: StepPhases, *, idle_frac: float = 0.0,
                    max_ticks: int = 4096) -> dict:
    """The paper's watermark controller on a synthetic timeline of
    outstanding collective bytes per link (reuses core/gating.gate_step,
    jitted as one lax.scan). The tick size adapts so one step is at most
    `max_ticks` ticks; sub-tick laser delays round up to one tick
    (conservative for the reactive policy)."""
    links = C.TPU_ICI_LINKS_PER_CHIP
    step_us = ph.step_us / max(1e-9, 1.0 - idle_frac)
    tick_us = max(1.0, step_us / max_ticks)
    n_ticks = max(int(step_us / tick_us), 1)
    t_layer = ph.t_compute_us + ph.t_collective_us
    demand = np.zeros(n_ticks)
    bw_link_tick = C.TPU_ICI_LINK_BW * 1e-6 * tick_us
    coll_bytes_layer = ph.t_collective_us * C.TPU_ICI_LINK_BW * 1e-6 * links
    for i in range(ph.n_layers):
        t0 = min(int((i * t_layer + ph.t_compute_us) / tick_us), n_ticks - 1)
        demand[t0] += coll_bytes_layer
    if ph.coll_tail_us > 0:
        t0 = min(int((ph.n_layers * t_layer + ph.t_tail_us) / tick_us),
                 n_ticks - 1)
        demand[t0] += ph.coll_tail_us * C.TPU_ICI_LINK_BW * 1e-6 * links

    cap_q = 8 * bw_link_tick
    up_delay = max(int(np.ceil(C.LASER_ON_US / tick_us)), 1)

    run = _reactive_program(links, bw_link_tick, tick_us, cap_q,
                            up_delay)
    powered_sum, stall_us = run(demand)
    on_frac = float(powered_sum) / (n_ticks * links)
    return {
        "policy": "reactive",
        "step_us": step_us,
        "tick_us": tick_us,
        "link_on_frac": on_frac,
        "ici_energy_savings": 1.0 - on_frac,
        "latency_penalty": float(stall_us) / max(step_us, 1e-9),
    }


def analyze_cell(arch: str, shape: str, *, idle_frac: float = 0.0,
                 mesh: str = "single") -> dict | None:
    f = RESULTS / f"{arch}__{shape}__{mesh}.json"
    if not f.exists():
        return None
    rec = json.loads(f.read_text())
    if not rec.get("ok"):
        return None
    ph = phases_from_dryrun(rec)
    if ph is None:
        return None
    return {
        "arch": arch, "shape": shape,
        "collective_duty": ph.collective_duty,
        "t_compute_us": ph.t_compute_us,
        "t_collective_us": ph.t_collective_us,
        "scheduled": scheduled_policy(ph, idle_frac=idle_frac),
        "reactive": reactive_policy(ph, idle_frac=idle_frac),
    }


def analyze_all(idle_frac: float = 0.0) -> list[dict]:
    out = []
    for f in sorted(RESULTS.glob("*__single.json")):
        arch, shape, _ = f.stem.split("__")
        r = analyze_cell(arch, shape, idle_frac=idle_frac)
        if r:
            out.append(r)
    return out
