"""LC/DC stage controller: watermark-driven link activation/deactivation.

Pure, vectorized over a leading switch axis so the same controller runs
the RSW tier (128 switches x 4 uplinks) and the CSW tier (16 x 4), and
the beyond-paper ICI study (chips x links).

Semantics (Sec III-A):
  * stage k active -> uplinks [0, k) usable; stage >= 1 always (full
    connectivity invariant - this is what hides the laser turn-on).
  * any active queue backlog > hi watermark -> raise stage-up trigger:
    after STAGE_UP_DELAY ticks (control msg + ack + laser on + CDR) the
    next link becomes usable.
  * all active backlogs < lo watermark -> stage-down: the top link stops
    accepting traffic (drain), and once its queue is empty it powers off
    after STAGE_OFF_DELAY ticks, during which it is still charged at
    full power (conservative, Sec VI-B).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import constants as C


class GateState(NamedTuple):
    stage: jnp.ndarray        # (S,) int32 in [1, n_links]
    up_timer: jnp.ndarray     # (S,) int32, >0 while a link is turning on
    draining: jnp.ndarray     # (S,) bool, top stage is draining
    off_timer: jnp.ndarray    # (S,) int32, >0 while top link powers off
    hold: jnp.ndarray         # (S,) int32 anti-flap dwell after activation
    # links charged as ON: active + turning-on + draining + turning-off
    powered: jnp.ndarray      # (S, L) bool


def gate_init(n_switches: int, n_links: int) -> GateState:
    stage = jnp.ones((n_switches,), jnp.int32)
    powered = jnp.zeros((n_switches, n_links), bool).at[:, 0].set(True)
    z = jnp.zeros((n_switches,), jnp.int32)
    return GateState(stage, z, jnp.zeros((n_switches,), bool), z, z,
                     powered)


def usable_links(stage: jnp.ndarray, draining: jnp.ndarray,
                 n_links: int) -> jnp.ndarray:
    """(S, L) bool: links a scheduler may enqueue to this tick.

    The single definition of "usable" shared by the gate controller, the
    pure-jnp switch-tick oracle (kernels/ref.py) and the Pallas switch
    kernel: links [0, stage) minus a draining top link (which still
    serves its backlog but accepts no new packets; stage 1 never drains).
    """
    idx = jnp.arange(n_links)[None, :]
    usable = idx < stage[:, None]
    top = idx == (stage[:, None] - 1)
    usable &= ~(draining[:, None] & top & (stage[:, None] > 1))
    return usable


def active_mask(state: GateState, n_links: int) -> jnp.ndarray:
    """(S, L) bool: links the scheduler may use this tick."""
    return usable_links(state.stage, state.draining, n_links)


def wake_stall_ticks(state: GateState) -> jnp.ndarray:
    """(S,) float32: remaining ticks of an in-flight stage-up.

    The wake stall a packet arriving NOW inherits from the pending
    ``STAGE_UP_DELAY`` transition (control msg + ack + laser turn-on +
    CDR lock): positive only while a link is rising, i.e. the extra
    capacity the hi watermark already asked for is not live yet. The
    single definition used by the simulator's delay-attribution
    accumulators; with gating disabled ``up_timer`` never leaves 0, so
    the attribution is exactly zero.
    """
    return state.up_timer.astype(jnp.float32)


def watermark_triggers(queues: jnp.ndarray, stage: jnp.ndarray,
                       *, cap: float, hi: float, lo: float):
    """Shared hi/lo backlog-monitor definition (Sec III-B).

    queues: (S, L) per-port monitored backlogs. Returns (hi_trig, lo_trig)
    bool (S,). Used by gate_step and by the switch-tick kernels so the
    watermark semantics cannot drift between the controller and the
    datapath. cap/hi/lo may each be scalar or per-switch (S,).
    """
    def per_switch(v):
        v = jnp.asarray(v)
        return v[:, None] if v.ndim == 1 else v   # broadcast over ports
    cap, hi, lo = per_switch(cap), per_switch(hi), per_switch(lo)
    idx = jnp.arange(queues.shape[1])[None, :]
    act = idx < stage[:, None]
    hi_t = jnp.any((queues > hi * cap) & act, axis=1)
    lo_t = jnp.all(jnp.where(act, queues < lo * cap, True), axis=1)
    return hi_t, lo_t


def gate_step(state: GateState, queues: jnp.ndarray,
              *, cap: float = C.QUEUE_CAP_PKTS,
              hi: float = C.HI_WATERMARK, lo: float = C.LO_WATERMARK,
              up_delay: int = C.STAGE_UP_DELAY_TICKS,
              off_delay: int = C.STAGE_OFF_DELAY_TICKS,
              dwell: int = C.STAGE_DWELL_TICKS,
              max_stage=None) -> GateState:
    """One controller tick. queues: (S, L) backlogs in packets.

    ``max_stage`` caps the stage per switch (scalar or (S,) int); it
    defaults to L. The padded multi-site sweep engine passes each
    switch's REAL link count so a site whose link axis is padded to a
    wider hull never activates links it does not physically have.
    """
    S, L = queues.shape
    idx = jnp.arange(L)[None, :]
    max_stage = jnp.asarray(L if max_stage is None else max_stage,
                            jnp.int32)

    hi_trig, lo_trig = watermark_triggers(queues, state.stage,
                                          cap=cap, hi=hi, lo=lo)

    stage, up_timer, draining, off_timer, hold = (
        state.stage, state.up_timer, state.draining, state.off_timer,
        state.hold)
    hold = jnp.maximum(hold - 1, 0)

    # --- stage-up: start turn-on unless at max / rising / powering off
    can_up = hi_trig & (stage < max_stage) & (up_timer == 0) \
        & (off_timer == 0)
    up_timer = jnp.where(can_up, up_delay, up_timer)
    # cancel a drain if load returned
    draining = jnp.where(hi_trig, False, draining)
    # countdown; on expiry the new link becomes usable
    fired = up_timer == 1
    stage = jnp.where(fired, jnp.minimum(stage + 1, max_stage), stage)
    hold = jnp.where(fired, dwell, hold)     # anti-flap dwell
    up_timer = jnp.maximum(up_timer - 1, 0)

    # --- stage-down: mark the top link draining (never stage 1)
    start_drain = lo_trig & (stage > 1) & ~draining & (up_timer == 0) \
        & (off_timer == 0) & (hold == 0)
    draining = draining | start_drain

    # drained? (top queue empty) -> drop the stage NOW (link unusable) and
    # begin the 10us power-off transition (still charged: off_timer)
    top_q = jnp.take_along_axis(queues, (stage - 1)[:, None],
                                axis=1)[:, 0]
    begin_off = draining & (top_q <= 0) & (stage > 1)
    stage = jnp.where(begin_off, stage - 1, stage)
    off_timer = jnp.where(begin_off, off_delay, off_timer)
    draining = jnp.where(begin_off, False, draining)
    off_timer = jnp.maximum(off_timer - 1, 0)

    # --- power accounting: on, rising, draining or falling => powered
    powered = idx < stage[:, None]
    powered |= (up_timer > 0)[:, None] & (idx == stage[:, None])  # rising
    powered |= (off_timer > 0)[:, None] & (idx == stage[:, None])  # falling
    powered |= draining[:, None] & (idx == (stage[:, None] - 1))

    return GateState(stage, up_timer, draining, off_timer, hold, powered)
