"""LC/DC stage controller: watermark-driven link activation/deactivation.

Pure, vectorized over a leading switch axis so the same controller runs
the RSW tier (128 switches x 4 uplinks) and the CSW tier (16 x 4), and
the beyond-paper ICI study (chips x links).

Semantics (Sec III-A):
  * stage k active -> uplinks [0, k) usable; stage >= 1 always (full
    connectivity invariant - this is what hides the laser turn-on).
  * any active queue backlog > hi watermark -> raise stage-up trigger:
    after STAGE_UP_DELAY ticks (control msg + ack + laser on + CDR) the
    next link becomes usable.
  * all active backlogs < lo watermark -> stage-down: the top link stops
    accepting traffic (drain), and once its queue is empty it powers off
    after STAGE_OFF_DELAY ticks, during which it is still charged at
    full power (conservative, Sec VI-B).

Optical fault model (opt-in, beyond-paper robustness axis)
----------------------------------------------------------
Real optical components are not the paper's perfect plane. ``gate_step``
grows an optional fault mode (engaged by passing ``link_ok``) with three
effects, each selected away bit-exactly when its knob is zero:

  * wake-time jitter: the turn-on delay becomes a per-event draw
    ``round(up_delay * (1 + jitter * (2u - 1)))`` (clamped >= 1) around
    the nominal instead of a constant;
  * transient wake failures: when the up-timer fires, the stage-up
    FAILS with probability ``wake_fail_prob`` and re-arms after a
    bounded ``WAKE_RETRY_BACKOFF_TICKS`` backoff plus a fresh turn-on
    delay (a flapping laser cannot hot-loop the controller);
  * min-connectivity fallback: hard transceiver faults (``FaultState``,
    evolved by ``fault_arrivals``) can leave a switch with zero usable
    healthy links. When that happens and a healthy real link exists,
    the policy force-wakes the CHEAPEST powered-off link (the lowest
    healthy index — raising the stage past it) the same tick, cancels
    any drain/off transition, and charges a fresh turn-on delay to the
    ``fault_stall`` attribution bin (``FaultState.wake``). Capacity is
    restored immediately in the fluid datapath; the stall is the
    latency price tag, exactly like the hi-watermark wake-stall split.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import constants as C


class GateState(NamedTuple):
    stage: jnp.ndarray        # (S,) int32 in [1, n_links]
    up_timer: jnp.ndarray     # (S,) int32, >0 while a link is turning on
    draining: jnp.ndarray     # (S,) bool, top stage is draining
    off_timer: jnp.ndarray    # (S,) int32, >0 while top link powers off
    hold: jnp.ndarray         # (S,) int32 anti-flap dwell after activation
    # links charged as ON: active + turning-on + draining + turning-off
    powered: jnp.ndarray      # (S, L) bool


class FaultState(NamedTuple):
    """Per-link hard-fault carry + the fault-forced wake stall.

    Lives alongside :class:`GateState` in the simulator's scan carry
    (kept separate so the controller state's contract is untouched and
    the fault axis stays optional for direct ``gate_step`` callers).
    """
    timer: jnp.ndarray    # (S, L) int32, > 0 while a transceiver is dead
    #                       (counts down the repair delay; 0 == healthy)
    wake: jnp.ndarray     # (S,) int32 remaining fault-forced wake stall
    #                       (feeds the fault_stall attribution bin)


def fault_init(n_switches: int, n_links: int) -> FaultState:
    return FaultState(jnp.zeros((n_switches, n_links), jnp.int32),
                      jnp.zeros((n_switches,), jnp.int32))


def fault_arrivals(timer: jnp.ndarray, u: jnp.ndarray,
                   powered: jnp.ndarray, link_real: jnp.ndarray,
                   fault_prob, repair_ticks,
                   plane_u: jnp.ndarray | None = None,
                   plane_fail_prob=0.0):
    """One tick of hard transceiver faults: Bernoulli arrivals on
    powered, healthy, REAL links (a dark or padded transceiver cannot
    die), then the repair countdown.

    timer: (S, L) int32 fault carry; u: (S, L) per-link uniforms;
    powered/link_real: (S, L) bool; fault_prob/repair_ticks: traced
    scalars (per-tick hazard = 1/MTBF, repair delay in ticks). Returns
    (new_timer, new_fault) with new_fault the (S, L) bool arrival mask
    (the simulator drops the dying link's queued packets into the
    fault-drop bin on it). ``fault_prob == 0`` leaves an all-zero timer
    all-zero — bit-inert.

    ``plane_u``/``plane_fail_prob`` model CORRELATED failure domains: a
    shared component (e.g. the laser comb feeding one optical plane)
    dying takes every link it feeds down in the same tick. ``plane_u``
    is an (S, L) uniform field in which all links of one plane carry
    the SAME draw (the caller broadcasts one draw per physical domain),
    so ``plane_u < plane_fail_prob`` strikes whole columns at once; the
    hit still only lands on powered, healthy, real links, and repairs
    share the per-link countdown. With ``plane_fail_prob == 0`` the OR
    adds an all-False mask (uniforms are >= 0, strict ``<``), so the
    default is structurally bit-inert — no epsilon, no new per-link
    stream consumed.
    """
    healthy = timer == 0
    hazard = u < fault_prob
    if plane_u is not None:
        hazard = hazard | (plane_u < plane_fail_prob)
    new_fault = healthy & powered & link_real & hazard
    timer = jnp.where(new_fault, jnp.asarray(repair_ticks, jnp.int32),
                      jnp.maximum(timer - 1, 0))
    return timer.astype(jnp.int32), new_fault


def fault_stall_ticks(fault: FaultState) -> jnp.ndarray:
    """(S,) float32: remaining ticks of a fault-forced link wake — the
    ``fault_stall`` delay-attribution analogue of ``wake_stall_ticks``.
    Exactly zero when no fallback wake is in flight (and with gating
    disabled, where the fallback never engages)."""
    return fault.wake.astype(jnp.float32)


def gate_init(n_switches: int, n_links: int) -> GateState:
    stage = jnp.ones((n_switches,), jnp.int32)
    powered = jnp.zeros((n_switches, n_links), bool).at[:, 0].set(True)
    z = jnp.zeros((n_switches,), jnp.int32)
    return GateState(stage, z, jnp.zeros((n_switches,), bool), z, z,
                     powered)


def usable_links(stage: jnp.ndarray, draining: jnp.ndarray,
                 n_links: int) -> jnp.ndarray:
    """(S, L) bool: links a scheduler may enqueue to this tick.

    The single definition of "usable" shared by the gate controller, the
    pure-jnp switch-tick oracle (kernels/ref.py) and the Pallas switch
    kernel: links [0, stage) minus a draining top link (which still
    serves its backlog but accepts no new packets; stage 1 never drains).
    """
    idx = jnp.arange(n_links)[None, :]
    usable = idx < stage[:, None]
    top = idx == (stage[:, None] - 1)
    usable &= ~(draining[:, None] & top & (stage[:, None] > 1))
    return usable


def active_mask(state: GateState, n_links: int) -> jnp.ndarray:
    """(S, L) bool: links the scheduler may use this tick."""
    return usable_links(state.stage, state.draining, n_links)


def wake_stall_ticks(state: GateState) -> jnp.ndarray:
    """(S,) float32: remaining ticks of an in-flight stage-up.

    The wake stall a packet arriving NOW inherits from the pending
    ``STAGE_UP_DELAY`` transition (control msg + ack + laser turn-on +
    CDR lock): positive only while a link is rising, i.e. the extra
    capacity the hi watermark already asked for is not live yet. The
    single definition used by the simulator's delay-attribution
    accumulators; with gating disabled ``up_timer`` never leaves 0, so
    the attribution is exactly zero.
    """
    return state.up_timer.astype(jnp.float32)


def stall_attribution(gate: GateState, fault: FaultState, gating_on):
    """(wake_stall, fault_stall) per switch, (S,) float32 each, masked
    to exactly 0.0 when ``gating_on`` is False.

    THE single stall-attribution pair: the simulator feeds it into both
    the packet-delay histogram and the flow engine's FCT samples, so
    wake/fault stalls attribute into flow completion times by
    construction — there is no second attribution path to drift. The
    mask belt-and-suspenders the structural invariants (``up_timer``
    never leaves 0 without gating, the fallback never engages), keeping
    the always-on attribution exactly zero.
    """
    wake = jnp.where(gating_on, wake_stall_ticks(gate), 0.0)
    fstall = jnp.where(gating_on, fault_stall_ticks(fault), 0.0)
    return wake, fstall


def watermark_triggers(queues: jnp.ndarray, stage: jnp.ndarray,
                       *, cap: float, hi: float, lo: float,
                       link_valid=None):
    """Shared hi/lo backlog-monitor definition (Sec III-B).

    queues: (S, L) per-port monitored backlogs. Returns (hi_trig, lo_trig)
    bool (S,). Used by gate_step and by the switch-tick kernels so the
    watermark semantics cannot drift between the controller and the
    datapath. cap/hi/lo may each be scalar or per-switch (S,).
    ``link_valid`` (optional (S, L) bool) restricts the monitor to the
    valid/healthy ports — a dead (hard-faulted) transceiver's backlog
    neither raises the hi trigger nor blocks the lo one.
    """
    def per_switch(v):
        v = jnp.asarray(v)
        return v[:, None] if v.ndim == 1 else v   # broadcast over ports
    cap, hi, lo = per_switch(cap), per_switch(hi), per_switch(lo)
    idx = jnp.arange(queues.shape[1])[None, :]
    act = idx < stage[:, None]
    if link_valid is not None:
        act = act & link_valid
    hi_t = jnp.any((queues > hi * cap) & act, axis=1)
    lo_t = jnp.all(jnp.where(act, queues < lo * cap, True), axis=1)
    return hi_t, lo_t


def gate_step(state: GateState, queues: jnp.ndarray,
              *, cap: float = C.QUEUE_CAP_PKTS,
              hi: float = C.HI_WATERMARK, lo: float = C.LO_WATERMARK,
              up_delay: int = C.STAGE_UP_DELAY_TICKS,
              off_delay: int = C.STAGE_OFF_DELAY_TICKS,
              dwell: int = C.STAGE_DWELL_TICKS,
              max_stage=None,
              link_ok=None, link_real=None, u_jitter=None, u_fail=None,
              wake_fail_prob=0.0, wake_jitter_frac=0.0,
              fault_wake=None, fallback=True,
              backoff: int = C.WAKE_RETRY_BACKOFF_TICKS):
    """One controller tick. queues: (S, L) backlogs in packets.

    ``max_stage`` caps the stage per switch (scalar or (S,) int); it
    defaults to L. The padded multi-site sweep engine passes each
    switch's REAL link count so a site whose link axis is padded to a
    wider hull never activates links it does not physically have.

    Fault mode (see module docstring) engages when ``link_ok`` — the
    (S, L) healthy-transceiver mask — is passed; it then returns
    ``(GateState, fault_wake', diag)`` instead of a bare GateState:

    ``link_real``     (S, L) bool, links that physically exist (defaults
                      to all); a switch whose REAL links are all faulted
                      is genuine connectivity loss — the fallback only
                      engages while a healthy real link remains.
    ``u_jitter``      (S,) uniforms driving the per-event turn-on delay
                      draw (``wake_jitter_frac`` around nominal).
    ``u_fail``        (S,) uniforms driving the transient wake failure
                      (``wake_fail_prob`` per firing; retry after
                      ``backoff`` + a fresh turn-on delay).
    ``fault_wake``    (S,) int32 carry of the fault-forced wake stall
                      (``FaultState.wake``); counted down here, re-armed
                      on a fallback force-wake.
    ``fallback``      bool (traced ok): enable the min-connectivity
                      force-wake.
    ``diag``          dict of (S,) bools: ``retries`` (a wake attempt
                      failed this tick), ``forced`` (the fallback fired).

    With ``wake_fail_prob == wake_jitter_frac == 0`` and ``link_ok``
    all-True the returned GateState is bit-identical to the legacy
    (fault-free) path — the zero-rate parity contract the simulator's
    one-program design relies on.
    """
    S, L = queues.shape
    idx = jnp.arange(L)[None, :]
    max_stage = jnp.asarray(L if max_stage is None else max_stage,
                            jnp.int32)
    fault_mode = link_ok is not None

    hi_trig, lo_trig = watermark_triggers(queues, state.stage,
                                          cap=cap, hi=hi, lo=lo)

    stage, up_timer, draining, off_timer, hold = (
        state.stage, state.up_timer, state.draining, state.off_timer,
        state.hold)
    hold = jnp.maximum(hold - 1, 0)

    if fault_mode:
        # per-event turn-on delay draw around nominal; jitter 0 -> the
        # round() is exactly the nominal (zero-rate bit-parity)
        up_f = jnp.asarray(up_delay, jnp.float32)
        eff_delay = jnp.maximum(jnp.round(
            up_f * (1.0 + wake_jitter_frac * (2.0 * u_jitter - 1.0))),
            1.0).astype(jnp.int32)                               # (S,)
    else:
        eff_delay = up_delay

    # --- stage-up: start turn-on unless at max / rising / powering off
    can_up = hi_trig & (stage < max_stage) & (up_timer == 0) \
        & (off_timer == 0)
    up_timer = jnp.where(can_up, eff_delay, up_timer)
    # cancel a drain if load returned
    draining = jnp.where(hi_trig, False, draining)
    # countdown; on expiry the new link becomes usable
    fired = up_timer == 1
    if fault_mode:
        # transient wake failure: the firing attempt fails and re-arms
        # after a bounded backoff plus a fresh turn-on delay
        failed = fired & (u_fail < wake_fail_prob)
        fired = fired & ~failed
    stage = jnp.where(fired, jnp.minimum(stage + 1, max_stage), stage)
    hold = jnp.where(fired, dwell, hold)     # anti-flap dwell
    up_timer = jnp.maximum(up_timer - 1, 0)
    if fault_mode:
        up_timer = jnp.where(failed, backoff + eff_delay, up_timer)

    # --- stage-down: mark the top link draining (never stage 1)
    start_drain = lo_trig & (stage > 1) & ~draining & (up_timer == 0) \
        & (off_timer == 0) & (hold == 0)
    draining = draining | start_drain

    # drained? (top queue empty) -> drop the stage NOW (link unusable) and
    # begin the 10us power-off transition (still charged: off_timer)
    top_q = jnp.take_along_axis(queues, (stage - 1)[:, None],
                                axis=1)[:, 0]
    begin_off = draining & (top_q <= 0) & (stage > 1)
    stage = jnp.where(begin_off, stage - 1, stage)
    off_timer = jnp.where(begin_off, off_delay, off_timer)
    draining = jnp.where(begin_off, False, draining)
    off_timer = jnp.maximum(off_timer - 1, 0)

    diag = None
    if fault_mode:
        # --- min-connectivity fallback: a switch whose usable prefix is
        # all dead force-wakes the cheapest healthy link (lowest index)
        # the same tick, so the datapath never sees a repairable switch
        # with zero usable links; the turn-on delay is charged to the
        # fault_stall attribution carry instead of stalling the fluid
        ok = link_ok if link_real is None else (link_ok & link_real)
        usable_ok = usable_links(stage, draining, L) & ok
        has_ok = jnp.any(ok, axis=1)
        do_fb = ~jnp.any(usable_ok, axis=1) & has_ok & fallback
        first_ok = jnp.argmax(ok, axis=1).astype(jnp.int32)
        tgt = jnp.minimum(first_ok + 1, max_stage)
        stage = jnp.where(do_fb, jnp.maximum(stage, tgt), stage)
        draining = jnp.where(do_fb, False, draining)
        off_timer = jnp.where(do_fb, 0, off_timer)
        hold = jnp.where(do_fb, jnp.asarray(dwell, jnp.int32), hold)
        fwake = jnp.maximum(jnp.asarray(fault_wake) - 1, 0)
        fwake = jnp.where(do_fb, eff_delay, fwake).astype(jnp.int32)
        diag = {"retries": failed, "forced": do_fb}

    # --- power accounting: on, rising, draining or falling => powered
    powered = idx < stage[:, None]
    powered |= (up_timer > 0)[:, None] & (idx == stage[:, None])  # rising
    powered |= (off_timer > 0)[:, None] & (idx == stage[:, None])  # falling
    powered |= draining[:, None] & (idx == (stage[:, None] - 1))

    out = GateState(stage, up_timer, draining, off_timer, hold, powered)
    if fault_mode:
        return out, fwake, diag
    return out
