"""Adafactor (factored second moment, no first moment) for the 1T-param
MoE arch: O(n+m) optimizer state per (n,m) matrix instead of Adam's 2nm.
Factored over the last two dims of >=2-D params; 1-D params keep a full
second moment."""
from __future__ import annotations

import jax
import jax.numpy as jnp

EPS1 = 1e-30


def _factored(p):
    return p.ndim >= 2


def adafactor_init(params):
    def init(p):
        if _factored(p):
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {
        "step": jnp.zeros((), jnp.int32),
        "v": jax.tree.map(init, params),
    }


def adafactor_update(grads, state, params, lr, *, decay=0.8, clip=1.0,
                     weight_decay=0.0, eps=1e-8):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** -decay

    def upd(g, v, p):
        g = g.astype(jnp.float32)
        g2 = g * g + EPS1
        if _factored(p):
            vr = beta * v["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
            vc = beta * v["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(jnp.mean(vr, -1, keepdims=True), EPS1) + eps)
            cfac = jax.lax.rsqrt(vc + eps)
            u = g * rfac[..., None] * cfac[..., None, :]
            nv = {"vr": vr, "vc": vc}
        else:
            nvv = beta * v["v"] + (1 - beta) * g2
            u = g * jax.lax.rsqrt(nvv + eps)
            nv = {"v": nvv}
        # update clipping by RMS
        rms = jnp.sqrt(jnp.mean(u * u) + EPS1)
        u = u / jnp.maximum(1.0, rms / clip)
        if weight_decay:
            u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

    leaves, treedef = jax.tree.flatten(params)
    gl = treedef.flatten_up_to(grads)
    vl = treedef.flatten_up_to(state["v"])
    out = [upd(g, v, p) for g, v, p in zip(gl, vl, leaves)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    return new_params, {"step": step, "v": new_v}
