from repro.optim.adamw import adamw_init, adamw_update
from repro.optim.adafactor import adafactor_init, adafactor_update
from repro.optim.schedule import cosine_warmup


def make_optimizer(cfg):
    """Returns (init_fn(params), update_fn(grads, state, params, lr))."""
    if cfg.optimizer == "adafactor":
        return adafactor_init, adafactor_update
    return adamw_init, adamw_update


__all__ = ["adamw_init", "adamw_update", "adafactor_init",
           "adafactor_update", "cosine_warmup", "make_optimizer"]
