"""AdamW with decoupled weight decay. Moments stored in fp32, sharded like
the parameters (ZeRO-style when FSDP specs shard the params)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    pl, treedef = jax.tree.flatten(params)
    gl = treedef.flatten_up_to(grads)
    ml = treedef.flatten_up_to(state["m"])
    vl = treedef.flatten_up_to(state["v"])
    out = [upd(g, m, v, p) for g, m, v, p in zip(gl, ml, vl, pl)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"step": step, "m": new_m, "v": new_v}
